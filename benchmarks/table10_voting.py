"""Paper Table 10: effect of consistent voting (on vs off)."""
from repro.core.fedkt import run_fedkt
from benchmarks.common import Emitter, fedcfg, make_tasks


def run(em: Emitter, quick=True):
    for task in make_tasks(quick):
        for cv in (True, False):
            cfg = fedcfg(task, consistent_voting=cv)
            res = run_fedkt(task.learner, task.data, cfg)
            em.emit("table10", task.name,
                    "consistent" if cv else "plain",
                    round(res.accuracy, 4))
