"""Paper Table 10: effect of consistent voting (on vs off)."""
from repro.federation import FedKTSession
from benchmarks.common import Emitter, fedcfg, make_tasks


def run(em: Emitter, quick=True):
    for task in make_tasks(quick):
        for cv in (True, False):
            cfg = fedcfg(task, consistent_voting=cv)
            res = FedKTSession(task.learner, task.data, cfg).run()
            em.emit("table10", task.name,
                    "consistent" if cv else "plain",
                    round(res.accuracy, 4))
