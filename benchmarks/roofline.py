"""Roofline table aggregator: reads the dry-run JSON records and renders
the §Roofline table (per arch x shape x mesh: three terms, dominant
bottleneck, MODEL_FLOPS/HLO ratio)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Emitter

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load_records(pattern="dryrun_*.json"):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(em: Emitter, quick=True):
    recs = load_records()
    ok = [r for r in recs if not r.get("error") and not r.get("skipped")]
    skipped = [r for r in recs if r.get("skipped")]
    failed = [r for r in recs if r.get("error")]
    em.emit("roofline", "summary", "lowered_ok", len(ok))
    em.emit("roofline", "summary", "skipped", len(skipped))
    em.emit("roofline", "summary", "failed", len(failed))
    for r in ok:
        key = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        em.emit("roofline", key, "t_compute_s",
                f"{r['t_compute']:.4e}")
        em.emit("roofline", key, "t_memory_s", f"{r['t_memory']:.4e}")
        em.emit("roofline", key, "t_collective_s",
                f"{r['t_collective']:.4e}")
        em.emit("roofline", key, "dominant", r["dominant"])
        em.emit("roofline", key, "useful_ratio",
                f"{r['useful_ratio']:.3f}")
    for r in failed:
        em.emit("roofline", f"{r['arch']}/{r['shape']}/{r['mesh']}",
                "ERROR", r["error"][:80])


def markdown_table(mesh="pod1_16x16") -> str:
    """Renders the EXPERIMENTS.md §Roofline table."""
    recs = [r for r in load_records() if r.get("mesh") == mesh]
    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s)"
        " | dominant | useful FLOP ratio | peak mem/dev (GB) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped: {r['skipped']} | — | — |")
            continue
        if r.get("error"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR | — | — |")
            continue
        pm = r.get("peak_memory_bytes")
        pm = f"{pm/1e9:.2f}" if pm else "n/a"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} | "
            f"{r['t_memory']:.3e} | {r['t_collective']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.3f} | {pm} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
