"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]

Emits CSV rows ``table,setting,metric,value``.  Default (quick) mode is
sized for a single CPU core; ``--full`` uses paper-scale settings.
The roofline section aggregates the dry-run artifacts produced by
``python -m repro.launch.dryrun --all``.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import Emitter

MODULES = [
    "table1_accuracy",
    "fig2_rounds",
    "table2_privacy",
    "table5_partitions",
    "table6_subsets",
    "table7_imbalance",
    "table10_voting",
    "engines_bench",
    "tree_fit_bench",
    "serve_bench",
    "comm_overhead",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    args = ap.parse_args()

    mods = MODULES if not args.only else [
        m for m in MODULES if m in set(args.only.split(","))]
    em = Emitter()
    print("table,setting,metric,value")
    t00 = time.time()
    failures = 0
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run(em, quick=not args.full)
            em.emit("_meta", name, "seconds", round(time.time() - t0, 1))
        except Exception as e:  # keep the harness going
            failures += 1
            em.emit("_meta", name, "ERROR", f"{type(e).__name__}: {e}")
    em.emit("_meta", "total", "seconds", round(time.time() - t00, 1))
    em.emit("_meta", "total", "failures", failures)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
