"""Paper Table 5: accuracy vs number of partitions s (1..4)."""
from repro.federation import FedKTSession
from benchmarks.common import Emitter, fedcfg, make_tasks


def run(em: Emitter, quick=True):
    task = make_tasks(quick)[0]
    for s in (1, 2, 3) if quick else (1, 2, 3, 4, 5):
        cfg = fedcfg(task, num_partitions=s)
        res = FedKTSession(task.learner, task.data, cfg).run()
        em.emit("table5", f"s={s}", "acc", round(res.accuracy, 4))
