"""Paper Table 7: heterogeneity sweep — Dirichlet beta in {0.1, 0.5, 10};
FedKT vs SOLO and 2-round FedAvg under each."""
from repro.core.baselines import IterConfig
from repro.core.partition import dirichlet_partition
from repro.federation import (FedKTStrategy, IterativeStrategy,
                              SoloStrategy)
from benchmarks.common import Emitter, fedcfg, make_tasks


def run(em: Emitter, quick=True):
    task = make_tasks(quick)[0]
    for beta in (0.1, 0.5, 10.0):
        cfg = fedcfg(task, beta=beta)
        parts = dirichlet_partition(task.data["y_train"], cfg.num_parties,
                                    beta, cfg.seed, min_size=10)
        res = FedKTStrategy(task.learner).run(
            task.data, cfg, party_indices=parts)
        em.emit("table7", f"beta={beta}", "FedKT", round(res.accuracy, 4))
        solo = SoloStrategy(task.learner).run(task.data, cfg,
                                              party_indices=parts)
        em.emit("table7", f"beta={beta}", "SOLO", round(solo.accuracy, 4))
        out = IterativeStrategy(
            task.net, IterConfig(algo="fedavg", rounds=2, local_steps=60),
            label="FedAvg-2r").run(task.data, cfg, party_indices=parts)
        em.emit("table7", f"beta={beta}", "FedAvg-2r",
                round(out.accuracy, 4))
