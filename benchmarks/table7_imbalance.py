"""Paper Table 7: heterogeneity sweep — Dirichlet beta in {0.1, 0.5, 10};
FedKT vs SOLO and 2-round FedAvg under each."""
from repro.core.baselines import IterConfig, run_iterative
from repro.core.fedkt import run_fedkt, run_solo
from repro.core.partition import dirichlet_partition
from benchmarks.common import Emitter, fedcfg, make_tasks


def run(em: Emitter, quick=True):
    task = make_tasks(quick)[0]
    for beta in (0.1, 0.5, 10.0):
        cfg = fedcfg(task, beta=beta)
        parts = dirichlet_partition(task.data["y_train"], cfg.num_parties,
                                    beta, cfg.seed, min_size=10)
        res = run_fedkt(task.learner, task.data, cfg, party_indices=parts)
        em.emit("table7", f"beta={beta}", "FedKT", round(res.accuracy, 4))
        em.emit("table7", f"beta={beta}", "SOLO",
                round(run_solo(task.learner, task.data, cfg,
                               party_indices=parts), 4))
        out = run_iterative(task.net, task.data,
                            IterConfig(algo="fedavg", rounds=2,
                                       local_steps=60),
                            party_indices=parts)
        em.emit("table7", f"beta={beta}", "FedAvg-2r",
                round(out["acc_per_round"][-1], 4))
