"""Paper Tables 2/14/15: differentially-private FedKT — (gamma, #queries)
-> (epsilon, accuracy), plus the moments-accountant vs advanced-
composition comparison (§B.7).  Runs through FedKTSession, whose
Server/Party split owns the L1/L2 accounting."""
from __future__ import annotations

import numpy as np

from repro.core import privacy as P
from repro.federation import FedKTSession

from benchmarks.common import Emitter, fedcfg, make_tasks


def run(em: Emitter, quick=True):
    task = make_tasks(quick)[0]          # tabular (paper uses Adult/cod-rna)
    for level, gammas in (("L1", (0.04, 0.1)), ("L2", (0.05, 0.1))):
        for gamma in gammas:
            for qf in (0.05, 0.2):
                cfg = fedcfg(task, privacy_level=level, gamma=gamma,
                             query_fraction=qf, num_partitions=1,
                             num_subsets=5)
                res = FedKTSession(task.learner, task.data,
                                   cfg).run()
                em.emit("table2", f"{level}-g{gamma}-q{qf}", "eps",
                        round(res.epsilon, 3))
                em.emit("table2", f"{level}-g{gamma}-q{qf}", "acc",
                        round(res.accuracy, 4))

    # accountant vs advanced composition on a fixed query trace
    gamma, s, k = 0.1, 1, 90
    gaps = np.full(k, 4.0)
    eps_ma = P.fedkt_l1_epsilon(gaps, gamma, s, num_classes=2)
    eps_adv = P.advanced_composition(2 * s * gamma, k, 1e-5)
    em.emit("table2", "accountant-comparison", "moments_eps",
            round(eps_ma, 3))
    em.emit("table2", "accountant-comparison", "advanced_comp_eps",
            round(eps_adv, 3))
