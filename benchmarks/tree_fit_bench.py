"""Tree-fit hot-path benchmark: per-level histogram builds and
end-to-end stacked tree fits, old scatter-add formulation vs
``ops.tree_hist``.

Two sections, written to BENCH_tree_fit.json at the repo root:

  hist_levels : one (node, feature, bin[, class]) histogram build per
      tree depth level at the quickstart rf teacher shape (a party's
      stacked 8-subset x 16-tree grid), legacy scatter-add vs the
      restructured ops.tree_hist auto path.  The scatter cost is flat
      in the node count (it always walks N*F elements); the matmul
      cost scales with live nodes, so shallow levels win hardest.
  fits : end-to-end ``fit_forest_stacked`` / ``fit_gbdt_stacked`` warm
      times at the same shapes the rf row of
      BENCH_federation_engines.json exercises.

Tiny-config smoke: ``bench(tiny=True, write=False)`` runs the same code
on toy shapes in a few seconds — invoked from tier-1 tests so this
script cannot rot.

    PYTHONPATH=src python -m benchmarks.tree_fit_bench
"""
from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import trees as T
from repro.kernels import ops

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_tree_fit.json")
REPEATS = 10


def _time(fn, *args, repeats=REPEATS):
    jax.block_until_ready(fn(*args))           # compile
    t0 = time.time()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / repeats


def _hist_scatter(xb, y, w, node, n_nodes, F, num_bins, C):
    """The pre-tree_hist per-level build: flat index + one giant 1-D
    scatter-add over an (N, F) broadcast of w (kept for comparison)."""
    N = xb.shape[0]
    flat = ((node[:, None] * F + jnp.arange(F)[None]) * num_bins
            + xb) * C + y[:, None]
    hist = jnp.zeros((n_nodes * F * num_bins * C,), jnp.float32)
    hist = hist.at[flat.reshape(-1)].add(
        jnp.broadcast_to(w[:, None], (N, F)).reshape(-1))
    return hist.reshape(n_nodes, F, num_bins, C)


def _hist_tree_hist(xb, y, w, node, n_nodes, num_bins, C):
    wc = jax.nn.one_hot(y, C, dtype=jnp.float32).T * w[None]
    return ops.tree_hist(xb, node, wc, num_nodes=n_nodes,
                         num_bins=num_bins, impl="auto")


def bench_hist_levels(k, t, n, f, num_bins, c, depth, repeats):
    """Per-level histogram build over the stacked (k, t) teacher grid."""
    rng = np.random.default_rng(0)
    xb = jnp.asarray(rng.integers(0, num_bins, (k, n, f)), jnp.int32)
    y = jnp.asarray(rng.integers(0, c, (k, n)), jnp.int32)
    w = jnp.asarray(rng.random((k, t, n)), jnp.float32)
    rows = {}
    for level in range(depth):
        n_nodes = 2 ** level
        node = jnp.asarray(rng.integers(0, n_nodes, (k, t, n)), jnp.int32)

        @jax.jit
        def scat(xb, y, w, node, n_nodes=n_nodes):
            fn = functools.partial(_hist_scatter, n_nodes=n_nodes, F=f,
                                   num_bins=num_bins, C=c)
            return jax.vmap(jax.vmap(fn, (None, None, 0, 0)))(xb, y, w,
                                                              node)

        @jax.jit
        def thist(xb, y, w, node, n_nodes=n_nodes):
            fn = functools.partial(_hist_tree_hist, n_nodes=n_nodes,
                                   num_bins=num_bins, C=c)
            return jax.vmap(jax.vmap(fn, (None, None, 0, 0)))(xb, y, w,
                                                              node)

        a = np.asarray(scat(xb, y, w, node))
        b = np.asarray(thist(xb, y, w, node).transpose(0, 1, 3, 4, 5, 2))
        np.testing.assert_allclose(a, b, atol=1e-3, rtol=1e-5)
        s = _time(scat, xb, y, w, node, repeats=repeats)
        h = _time(thist, xb, y, w, node, repeats=repeats)
        rows[f"level{level}_nodes{n_nodes}"] = {
            "scatter_ms": round(s * 1e3, 3),
            "tree_hist_ms": round(h * 1e3, 3),
            "speedup": round(s / h, 2),
        }
    return rows


def bench_fits(k, t, n, f, depth, rounds, repeats):
    """End-to-end stacked fits at the federation bench shapes."""
    rng = np.random.default_rng(1)
    Xs = rng.normal(0, 1, (k, n, f)).astype(np.float32)
    ys = rng.integers(0, 2, (k, n)).astype(np.int32)
    edges = jnp.asarray(np.stack([T.make_bins(Xs[i]) for i in range(k)]))
    Xj, yj = jnp.asarray(Xs), jnp.asarray(ys)
    w_rf = jnp.asarray(rng.random((k, t, n)), jnp.float32)
    fm = jnp.ones((k, t, f), jnp.float32)
    w_gb = jnp.ones((k, n), jnp.float32)

    def rf(X, e, y, w, m):
        return T.fit_forest_stacked(X, e, y, w, m, depth=depth,
                                    num_classes=2)

    def gb(X, e, y, w):
        return T.fit_gbdt_stacked(X, e, y, w, 0.3, num_rounds=rounds,
                                  depth=max(depth - 2, 1))

    return {
        "rf_stacked": {
            "shape": f"k={k} trees={t} N={n} F={f} depth={depth}",
            "warm_ms": round(_time(rf, Xj, edges, yj, w_rf, fm,
                                   repeats=repeats) * 1e3, 2)},
        "gbdt_stacked": {
            "shape": f"k={k} N={n} F={f} rounds={rounds} "
                     f"depth={max(depth - 2, 1)}",
            "warm_ms": round(_time(gb, Xj, edges, yj, w_gb,
                                   repeats=repeats) * 1e3, 2)},
    }


def bench(tiny=False, write=True, repeats=None):
    if tiny:      # smoke shapes: seconds, exercises every code path
        shape = dict(k=2, t=3, n=64, f=5, num_bins=T.NUM_BINS, c=2,
                     depth=2)
        fit_kw = dict(k=2, t=3, n=64, f=5, depth=3, rounds=2)
        repeats = repeats or 1
    else:         # the quickstart rf bench teacher grid (one party)
        shape = dict(k=8, t=16, n=128, f=14, num_bins=T.NUM_BINS, c=2,
                     depth=5)
        fit_kw = dict(k=8, t=16, n=128, f=14, depth=5, rounds=10)
        repeats = repeats or REPEATS
    rec = {
        "impl_auto_resolves_to": ops.resolve_impl("auto"),
        "hist_shape": shape,
        "hist_levels": bench_hist_levels(repeats=repeats, **shape),
        "fits": bench_fits(repeats=repeats, **fit_kw),
    }
    if write:
        with open(OUT, "w") as fh:
            json.dump(rec, fh, indent=1)
            fh.write("\n")
    return rec


def run(em, quick=True):
    """benchmarks.run entry: quick mode never overwrites the committed
    BENCH record."""
    rec = bench(tiny=quick, write=not quick)
    for name, row in rec["hist_levels"].items():
        em.emit("tree_fit", name, "scatter_ms", row["scatter_ms"])
        em.emit("tree_fit", name, "tree_hist_ms", row["tree_hist_ms"])
        em.emit("tree_fit", name, "speedup", row["speedup"])
    for name, row in rec["fits"].items():
        em.emit("tree_fit", name, "warm_ms", row["warm_ms"])


if __name__ == "__main__":
    print(json.dumps(bench(), indent=1))
