"""Federation engine benchmark: serial "loop" vs batched "vmap" teacher
AND student execution on the quickstart config (5 parties x 2
partitions x 4 teachers).

Two learner rows, covering both sides of the paper's model-agnosticism
claim:

  nn : tabular MLP teachers (differentiable — the original 1.86x row)
  rf : random-forest teachers (non-differentiable; the models FedAvg
       cannot federate).  The vmap engine trains each party's whole s*t
       teacher grid as one stacked histogram fit, and its s students as
       one stacked fit — with zero-weight padding the results are
       bit-identical to the serial loop.

A third, parallel-parties row fans the nn config's five parties out
over the thread transport (loop engine — its per-party dispatch gaps
are what fan-out overlaps; vmap already saturates the cores from one
party) and records the MEASURED PartyUpdate wire bytes — the
codec-framed size that actually crossed the party/server boundary, not
a pytree-size estimate.

A fourth, fleet-scale row runs 128 simulated parties over the socket
transport: each party ships its update through a real localhost TCP
connection, and the server streams arrivals into one running vote
histogram (retain_students=False — constant memory in the party
count).  The row records the measured framed bytes that crossed the
sockets and the streamed round's wall-clock.  A companion row
(nn_fleet_socket_journal) reruns the same fleet with the write-ahead
round journal on — every accepted frame fsync'd before its ACK — and
records the fsync overhead relative to the journal-less row plus the
journal's on-disk footprint.

A fifth, heterogeneous row (het_mixed_3way) federates one rf, one
gbdt, and one nn silo through per-party bindings — trees on the vmap
engine, the MLP on the loop — and records the measured framed wire
bytes PER MODEL FAMILY: a mixed fleet is priced per family, not per
average party.

A sixth, vertical row (vertical_3silo) runs the feature-split
scenario: three silos holding the SAME samples and disjoint column
slices (core.partition.vertical_split + feature_mask= learners)
federate over the socket transport, folding into one shared example
vote domain; records the measured framed bytes per domain.

All engines and transports run the identical protocol and PRNG
schedule.  Writes the headline numbers to BENCH_federation_engines.json
at the repo root.

    PYTHONPATH=src python -m benchmarks.engines_bench
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.configs.base import FedKTConfig
from repro.core.learners import NNLearner, RFLearner
from repro.data.synthetic import tabular_binary
from repro.federation import FedKTSession
from repro.models.smallnets import MLP

OUT = os.path.join(os.path.dirname(__file__), "..",
                   "BENCH_federation_engines.json")
REPEATS = 3

QUICKSTART = dict(num_parties=5, num_partitions=2, num_subsets=4,
                  num_classes=2, beta=0.5)


def nn_setup():
    data = tabular_binary(n=6000, seed=0)
    learner = NNLearner(MLP(num_features=14, num_classes=2, hidden=32),
                        num_classes=2, steps=200)
    return learner, data, FedKTConfig(**QUICKSTART), \
        "NNLearner(MLP-32, steps=200)"


def rf_setup():
    data = tabular_binary(n=6000, seed=0)
    learner = RFLearner(num_classes=2, num_trees=16, depth=5)
    return learner, data, FedKTConfig(**QUICKSTART), \
        "RFLearner(trees=16, depth=5)"


SETUPS = {"nn": nn_setup, "rf": rf_setup}


def bench_one(setup, repeats):
    learner, data, cfg, desc = setup()
    row = {"config": {"num_parties": cfg.num_parties,
                      "num_partitions": cfg.num_partitions,
                      "num_subsets": cfg.num_subsets,
                      "learner": desc,
                      "n_train": len(data["X_train"])},
           "engines": {}}
    results = {}
    for engine in ("loop", "vmap"):
        session = FedKTSession(learner, data, cfg, engine=engine)
        t0 = time.time()
        res = session.run()
        cold = time.time() - t0
        warms = []
        for _ in range(repeats):
            t0 = time.time()
            res = FedKTSession(learner, data, cfg, engine=engine).run()
            warms.append(time.time() - t0)
        results[engine] = res
        row["engines"][engine] = {
            "cold_s": round(cold, 3),
            "warm_s": round(sorted(warms)[len(warms) // 2], 3),
            "warm_runs_s": [round(w, 3) for w in warms],
            "accuracy": round(res.accuracy, 4),
        }
    e = row["engines"]
    row["warm_speedup_vmap_over_loop"] = round(
        e["loop"]["warm_s"] / e["vmap"]["warm_s"], 2)
    row["accuracies_agree"] = bool(
        results["loop"].accuracy == results["vmap"].accuracy)
    return row


def bench_parallel_parties(setup, repeats):
    """Parallel-parties row: serial in-process transport vs the thread
    transport (one worker per party), plus the measured codec-framed
    PartyUpdate wire bytes.  Uses the loop engine: its per-party
    dispatch gaps are what fan-out overlaps (the vmap engine already
    saturates the host's cores from a single party, so threads add
    nothing there)."""
    learner, data, cfg, desc = setup()
    row = {"config": {"num_parties": cfg.num_parties,
                      "num_partitions": cfg.num_partitions,
                      "num_subsets": cfg.num_subsets,
                      "learner": desc, "engine": "loop",
                      "parallelism": cfg.num_parties},
           "transports": {}}
    results = {}
    for transport in ("inprocess", "thread"):
        kw = dict(engine="loop", transport=transport)
        if transport != "inprocess":
            kw["parallelism"] = cfg.num_parties
        t0 = time.time()
        res = FedKTSession(learner, data, cfg, **kw).run()
        cold = time.time() - t0
        warms = []
        for _ in range(repeats):
            t0 = time.time()
            res = FedKTSession(learner, data, cfg, **kw).run()
            warms.append(time.time() - t0)
        results[transport] = res
        row["transports"][transport] = {
            "cold_s": round(cold, 3),
            "warm_s": round(sorted(warms)[len(warms) // 2], 3),
            "warm_runs_s": [round(w, 3) for w in warms],
            "accuracy": round(res.accuracy, 4),
            "parties_s": res.meta["seconds"]["parties"],
        }
    t = row["transports"]
    row["warm_speedup_thread_over_inprocess"] = round(
        t["inprocess"]["warm_s"] / t["thread"]["warm_s"], 2)
    row["accuracies_agree"] = bool(
        results["inprocess"].accuracy == results["thread"].accuracy)
    wire = results["thread"].meta["wire_bytes"]
    row["wire_bytes"] = {
        "updates_measured": wire["updates"],          # codec-framed truth
        "updates_payload": wire["updates_payload"],   # raw-array accounting
        "labels": wire["labels"],
    }
    return row


def fleet_setup():
    data = tabular_binary(n=8192, seed=0)
    learner = NNLearner(MLP(num_features=14, num_classes=2, hidden=8),
                        num_classes=2, steps=20)
    cfg = FedKTConfig(num_parties=128, num_partitions=1, num_subsets=2,
                      num_classes=2, seed=0)
    return learner, data, cfg, "NNLearner(MLP-8, steps=20)"


def bench_fleet_socket(repeats):
    """Fleet-scale row: 128 simulated parties deliver over localhost
    TCP, the server folds each arriving update into the ONE running
    vote histogram (retain_students=False — constant server memory in
    the party count).  Records the measured codec-framed bytes that
    crossed the sockets and the wall-clock of the streamed round.
    Equal-size shards keep the whole fleet in one pow2 training bucket,
    so the 128 parties share one compiled teacher/student fit."""
    from repro.federation.net import SocketTransport
    learner, data, cfg, desc = fleet_setup()
    rows = (len(data["X_train"]) // cfg.num_parties) * cfg.num_parties
    shards = np.array_split(np.arange(rows), cfg.num_parties)
    row = {"config": {"num_parties": cfg.num_parties,
                      "num_partitions": cfg.num_partitions,
                      "num_subsets": cfg.num_subsets,
                      "learner": desc, "engine": "loop",
                      "rows_per_party": rows // cfg.num_parties,
                      "parallelism": 8,
                      "retain_students": False},
           "transports": {}}

    def one_run():
        return FedKTSession(
            learner, data, cfg, engine="loop", party_indices=shards,
            retain_students=False,
            transport=SocketTransport(parallelism=8)).run()

    t0 = time.time()
    res = one_run()
    cold = time.time() - t0
    warms = []
    for _ in range(repeats):
        t0 = time.time()
        res = one_run()
        warms.append(time.time() - t0)
    report = res.meta["socket"]
    row["transports"]["socket"] = {
        "cold_s": round(cold, 3),
        "warm_s": round(sorted(warms)[len(warms) // 2], 3),
        "warm_runs_s": [round(w, 3) for w in warms],
        "accuracy": round(res.accuracy, 4),
        "parties_s": res.meta["seconds"]["parties"],
    }
    wire = res.meta["wire_bytes"]
    row["arrived"] = len(report["arrived"])
    row["dropped"] = report["dropped"]
    row["wire_bytes"] = {
        "updates_measured": wire["updates"],          # codec-framed truth
        "updates_payload": wire["updates_payload"],   # raw-array accounting
        "per_party_framed": wire["updates"] // cfg.num_parties,
        "labels": wire["labels"],
    }
    return row


def bench_fleet_socket_journal(repeats):
    """Crash-safety overhead row: the SAME 128-party streamed round as
    nn_fleet_socket, but with the write-ahead round journal on — every
    accepted frame is appended and fsync'd before its ACK/fold.  The
    headline number is the journal's cost on the fleet round's
    wall-clock (bench() records the warm ratio vs the journal-less
    row); the journal file size is the durability footprint of the
    whole round."""
    import tempfile
    from repro.federation.net import SocketTransport
    learner, data, cfg, desc = fleet_setup()
    rows_n = (len(data["X_train"]) // cfg.num_parties) * cfg.num_parties
    shards = np.array_split(np.arange(rows_n), cfg.num_parties)
    path = os.path.join(tempfile.mkdtemp(), "fleet.jrnl")
    row = {"config": {"num_parties": cfg.num_parties,
                      "num_partitions": cfg.num_partitions,
                      "num_subsets": cfg.num_subsets,
                      "learner": desc, "engine": "loop",
                      "parallelism": 8,
                      "retain_students": False,
                      "journal": True}}

    def one_run():
        if os.path.exists(path):
            os.remove(path)     # each run is a FRESH round, not a resume
        return FedKTSession(
            learner, data, cfg, engine="loop", party_indices=shards,
            retain_students=False,
            transport=SocketTransport(parallelism=8,
                                      journal_path=path)).run()

    t0 = time.time()
    res = one_run()
    cold = time.time() - t0
    warms = []
    for _ in range(repeats):
        t0 = time.time()
        res = one_run()
        warms.append(time.time() - t0)
    report = res.meta["socket"]
    row["cold_s"] = round(cold, 3)
    row["warm_s"] = round(sorted(warms)[len(warms) // 2], 3)
    row["warm_runs_s"] = [round(w, 3) for w in warms]
    row["accuracy"] = round(res.accuracy, 4)
    row["arrived"] = len(report["arrived"])
    row["journal_bytes"] = os.path.getsize(path)
    row["fsyncs"] = cfg.num_parties + 1        # header + one per frame
    os.remove(path)
    return row


def het_setup():
    from repro.core.learners import GBDTLearner
    from repro.federation import PartyBinding
    data = tabular_binary(n=6000, seed=0)
    bindings = [
        PartyBinding(RFLearner(num_classes=2, num_trees=16, depth=5),
                     engine="vmap"),
        PartyBinding(GBDTLearner(num_rounds=16, depth=4),
                     engine="vmap"),
        PartyBinding(NNLearner(MLP(num_features=14, num_classes=2,
                                   hidden=32), num_classes=2,
                               steps=200)),
    ]
    final = NNLearner(MLP(num_features=14, num_classes=2, hidden=32),
                      num_classes=2, steps=200)
    cfg = FedKTConfig(**{**QUICKSTART, "num_parties": 3})
    return bindings, final, data, cfg, \
        "rf(trees=16,d=5) + gbdt(rounds=16,d=4) + nn(MLP-32,steps=200)"


def bench_het_mixed(repeats):
    """Heterogeneous row: one 3-party rf + gbdt + nn round through
    per-party bindings (trees on the vmap engine, nn on the loop) over
    the thread transport.  The headline numbers are the MEASURED
    codec-framed wire bytes per model family — tree students ship
    split/leaf tables, the MLP ships dense weights, and a mixed fleet
    is priced per family, not per average party."""
    bindings, final, data, cfg, desc = het_setup()

    def one_run():
        return FedKTSession(bindings, data, cfg, final_learner=final,
                            transport="thread",
                            parallelism=cfg.num_parties).run()

    t0 = time.time()
    res = one_run()
    cold = time.time() - t0
    warms = []
    for _ in range(repeats):
        t0 = time.time()
        res = one_run()
        warms.append(time.time() - t0)
    wire = res.meta["wire_bytes"]
    return {
        "config": {"num_parties": cfg.num_parties,
                   "num_partitions": cfg.num_partitions,
                   "num_subsets": cfg.num_subsets,
                   "learner": desc, "engine": res.meta["engine"],
                   "party_bindings": res.meta["party_bindings"],
                   "transport": "thread", "n_train": len(data["X_train"])},
        "cold_s": round(cold, 3),
        "warm_s": round(sorted(warms)[len(warms) // 2], 3),
        "warm_runs_s": [round(w, 3) for w in warms],
        "accuracy": round(res.accuracy, 4),
        "wire_bytes": {
            "updates_measured": wire["updates"],        # codec-framed truth
            "updates_payload": wire["updates_payload"],
            "by_learner_kind": wire["by_learner_kind"],
            "per_party": {str(k): v
                          for k, v in wire["per_party"].items()},
            "labels": wire["labels"],
        },
    }


def vertical_setup():
    from repro.core.partition import vertical_split
    from repro.federation import PartyBinding
    data = tabular_binary(n=6000, seed=0)
    row_order, masks = vertical_split(
        np.arange(len(data["X_train"])), 14, 3, seed=0)
    bindings = [
        PartyBinding(NNLearner(MLP(num_features=len(masks[0]),
                                   num_classes=2, hidden=32),
                               num_classes=2, steps=200,
                               feature_mask=masks[0])),
        PartyBinding(RFLearner(num_classes=2, num_trees=16, depth=5,
                               feature_mask=masks[1]), engine="vmap"),
        PartyBinding(NNLearner(MLP(num_features=len(masks[2]),
                                   num_classes=2, hidden=32),
                               num_classes=2, steps=200,
                               feature_mask=masks[2])),
    ]
    final = NNLearner(MLP(num_features=14, num_classes=2, hidden=32),
                      num_classes=2, steps=200)
    cfg = FedKTConfig(**{**QUICKSTART, "num_parties": 3})
    indices = [row_order.copy() for _ in range(3)]
    return bindings, final, indices, masks, data, cfg, \
        "vertical nn+rf+nn (feature-masked, 14 cols over 3 silos)"


def bench_vertical(repeats):
    """Vertical row: the feature-split scenario of
    examples/vertical_fedkt.py at bench scale — every silo holds ALL
    samples and a disjoint column slice, trains feature-masked
    learners, and delivers over localhost TCP.  All three silos fold
    into ONE shared example vote domain (the cross-party contract is
    the domain, not the features), and the row records the measured
    codec-framed bytes broken down by that domain."""
    from repro.federation.net import SocketTransport
    bindings, final, indices, masks, data, cfg, desc = vertical_setup()

    def one_run():
        return FedKTSession(bindings, data, cfg, final_learner=final,
                            party_indices=[ix.copy() for ix in indices],
                            transport=SocketTransport(
                                parallelism=cfg.num_parties)).run()

    t0 = time.time()
    res = one_run()
    cold = time.time() - t0
    warms = []
    for _ in range(repeats):
        t0 = time.time()
        res = one_run()
        warms.append(time.time() - t0)
    wire = res.meta["wire_bytes"]
    return {
        "config": {"num_parties": cfg.num_parties,
                   "num_partitions": cfg.num_partitions,
                   "num_subsets": cfg.num_subsets,
                   "learner": desc, "transport": "socket",
                   "feature_masks": [list(m) for m in masks],
                   "n_train": len(data["X_train"])},
        "cold_s": round(cold, 3),
        "warm_s": round(sorted(warms)[len(warms) // 2], 3),
        "warm_runs_s": [round(w, 3) for w in warms],
        "accuracy": round(res.accuracy, 4),
        "domains": sorted(res.by_domain),
        "wire_bytes": {
            "updates_measured": wire["updates"],        # codec-framed truth
            "updates_payload": wire["updates_payload"],
            "by_domain": wire["by_domain"],
            "by_learner_kind": wire["by_learner_kind"],
            "labels": wire["labels"],
        },
    }


def bench(repeats=REPEATS, write=True, names=None):
    rec = {"repeats": repeats, "benches": {}}
    for name in (names or SETUPS):
        rec["benches"][name] = bench_one(SETUPS[name], repeats)
    if names is None or "nn" in names:
        rec["benches"]["nn_parallel_parties"] = bench_parallel_parties(
            nn_setup, repeats)
        rec["benches"]["nn_fleet_socket"] = bench_fleet_socket(repeats)
        jrow = bench_fleet_socket_journal(repeats)
        base = rec["benches"]["nn_fleet_socket"]["transports"]["socket"]
        jrow["warm_overhead_vs_nn_fleet_socket"] = round(
            jrow["warm_s"] / base["warm_s"], 3)
        rec["benches"]["nn_fleet_socket_journal"] = jrow
        rec["benches"]["het_mixed_3way"] = bench_het_mixed(repeats)
        rec["benches"]["vertical_3silo"] = bench_vertical(repeats)
    if write:
        with open(OUT, "w") as f:
            json.dump(rec, f, indent=1)
            f.write("\n")
    return rec


def run(em, quick=True):
    """benchmarks.run entry: one warm repeat in quick mode, and never
    overwrite the committed BENCH record with quick-mode numbers."""
    rec = bench(repeats=1 if quick else REPEATS, write=not quick)
    for name, row in rec["benches"].items():
        for engine, r in row.get("engines", {}).items():
            em.emit("engines", f"{name}/{engine}", "warm_s", r["warm_s"])
            em.emit("engines", f"{name}/{engine}", "acc", r["accuracy"])
        for transport, r in row.get("transports", {}).items():
            em.emit("engines", f"{name}/{transport}", "warm_s",
                    r["warm_s"])
        if "warm_speedup_vmap_over_loop" in row:
            em.emit("engines", f"{name}/vmap_over_loop", "warm_speedup",
                    row["warm_speedup_vmap_over_loop"])
        if "wire_bytes" in row:
            em.emit("engines", f"{name}/wire", "updates_measured_bytes",
                    row["wire_bytes"]["updates_measured"])
            for kind, nbytes in sorted(
                    row["wire_bytes"].get("by_learner_kind",
                                          {}).items()):
                em.emit("engines", f"{name}/wire/{kind}",
                        "framed_bytes", nbytes)
            for dom, nbytes in sorted(
                    row["wire_bytes"].get("by_domain", {}).items()):
                em.emit("engines", f"{name}/wire/domain/{dom}",
                        "framed_bytes", nbytes)
        if "warm_s" in row:        # single-variant rows (het_mixed_3way)
            em.emit("engines", name, "warm_s", row["warm_s"])
            em.emit("engines", name, "acc", row["accuracy"])


if __name__ == "__main__":
    rec = bench()
    print(json.dumps(rec, indent=1))
