"""Federation engine benchmark: serial "loop" vs batched "vmap" teacher
execution on the quickstart config (5 parties x 2 partitions x 4
teachers, tabular MLP).

The vmap engine trains each party's whole s*t teacher grid as one
batched jit dispatch instead of s*t sequential ones; both engines run
the identical protocol and PRNG schedule.  Writes the headline numbers
to BENCH_federation_engines.json at the repo root.

    PYTHONPATH=src python -m benchmarks.engines_bench
"""
from __future__ import annotations

import json
import os
import time

from repro.configs.base import FedKTConfig
from repro.core.learners import NNLearner
from repro.data.synthetic import tabular_binary
from repro.federation import FedKTSession
from repro.models.smallnets import MLP

OUT = os.path.join(os.path.dirname(__file__), "..",
                   "BENCH_federation_engines.json")
REPEATS = 3


def quickstart_setup():
    data = tabular_binary(n=6000, seed=0)
    learner = NNLearner(MLP(num_features=14, num_classes=2, hidden=32),
                        num_classes=2, steps=200)
    cfg = FedKTConfig(num_parties=5, num_partitions=2, num_subsets=4,
                      num_classes=2, beta=0.5)
    return learner, data, cfg


def bench(repeats=REPEATS, write=True):
    learner, data, cfg = quickstart_setup()
    rec = {"config": {"num_parties": cfg.num_parties,
                      "num_partitions": cfg.num_partitions,
                      "num_subsets": cfg.num_subsets,
                      "learner": "NNLearner(MLP-32, steps=200)",
                      "n_train": len(data["X_train"])},
           "repeats": repeats, "engines": {}}
    results = {}
    for engine in ("loop", "vmap"):
        session = FedKTSession(learner, data, cfg, engine=engine)
        t0 = time.time()
        res = session.run()
        cold = time.time() - t0
        warms = []
        for _ in range(repeats):
            t0 = time.time()
            res = FedKTSession(learner, data, cfg, engine=engine).run()
            warms.append(time.time() - t0)
        results[engine] = res
        rec["engines"][engine] = {
            "cold_s": round(cold, 3),
            "warm_s": round(sorted(warms)[len(warms) // 2], 3),
            "warm_runs_s": [round(w, 3) for w in warms],
            "accuracy": round(res.accuracy, 4),
        }
    e = rec["engines"]
    rec["warm_speedup_vmap_over_loop"] = round(
        e["loop"]["warm_s"] / e["vmap"]["warm_s"], 2)
    rec["accuracies_agree"] = bool(
        results["loop"].accuracy == results["vmap"].accuracy)
    if write:
        with open(OUT, "w") as f:
            json.dump(rec, f, indent=1)
            f.write("\n")
    return rec


def run(em, quick=True):
    """benchmarks.run entry: one warm repeat in quick mode, and never
    overwrite the committed BENCH record with quick-mode numbers."""
    rec = bench(repeats=1 if quick else REPEATS, write=not quick)
    for engine, r in rec["engines"].items():
        em.emit("engines", engine, "warm_s", r["warm_s"])
        em.emit("engines", engine, "acc", r["accuracy"])
    em.emit("engines", "vmap/loop", "warm_speedup",
            rec["warm_speedup_vmap_over_loop"])


if __name__ == "__main__":
    rec = bench()
    print(json.dumps(rec, indent=1))
