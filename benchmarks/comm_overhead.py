"""Paper §3 overhead analysis: FedKT total communication n*M*(s+1) vs
FedAvg 2*n*M*r — evaluated with the wire codec's MEASURED encoded model
sizes (framed header + payload, exactly what ``SubprocessTransport``
puts on the wire), across the assigned architectures."""
from __future__ import annotations

import jax

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.federation import codec, pytree_bytes
from repro.models import Model
from benchmarks.common import Emitter


def _model_shapes(cfg):
    model = Model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def run(em: Emitter, quick=True):
    n, s = 10, 2
    archs = ARCH_IDS if not quick else ARCH_IDS[:4]
    for arch in archs:
        shapes = _model_shapes(get_config(arch))
        # exact encoded size (codec.encoded_nbytes works on eval_shape
        # trees, so multi-GB models are priced without materializing)
        M = codec.encoded_nbytes(shapes)
        fedkt = n * M * (s + 1)
        em.emit("overhead", arch, "model_bytes", M)
        em.emit("overhead", arch, "model_payload_bytes",
                pytree_bytes(shapes))
        em.emit("overhead", arch, "fedkt_total_bytes", fedkt)
        for r in (2, 10, 50):
            em.emit("overhead", arch, f"fedavg_{r}r_bytes", 2 * n * M * r)
        # break-even rounds (paper: r > (s+1)/2)
        em.emit("overhead", arch, "breakeven_rounds", (s + 1) / 2)
