"""Paper §3 overhead analysis: FedKT total communication n*M*(s+1) vs
FedAvg 2*n*M*r — evaluated with REAL serialized model sizes from the
framework's checkpointing, across the assigned architectures."""
from __future__ import annotations

import jax

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import Model
from benchmarks.common import Emitter


def _param_bytes(cfg) -> int:
    model = Model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(shapes))


def run(em: Emitter, quick=True):
    n, s = 10, 2
    archs = ARCH_IDS if not quick else ARCH_IDS[:4]
    for arch in archs:
        M = _param_bytes(get_config(arch))
        fedkt = n * M * (s + 1)
        em.emit("overhead", arch, "model_bytes", M)
        em.emit("overhead", arch, "fedkt_total_bytes", fedkt)
        for r in (2, 10, 50):
            em.emit("overhead", arch, f"fedavg_{r}r_bytes", 2 * n * M * r)
        # break-even rounds (paper: r > (s+1)/2)
        em.emit("overhead", arch, "breakeven_rounds", (s + 1) / 2)
