"""Shared benchmark fixtures: tasks, learners, result formatting.

Each benchmark module reproduces one paper table/figure on the synthetic
stand-in tasks (DESIGN.md §2) and emits CSV rows:
    table,setting,metric,value
``--full`` uses paper-scale parties/trials; the default quick mode keeps
``python -m benchmarks.run`` in CI-friendly time on one CPU core.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from repro.configs.base import FedKTConfig
from repro.core.learners import GBDTLearner, NNLearner, RFLearner
from repro.data.synthetic import digits, tabular_binary
from repro.models.smallnets import MLP, PaperCNN


@dataclass
class Task:
    name: str
    data: Dict[str, np.ndarray]
    learner: object
    num_classes: int
    num_parties: int
    net: object = None


def make_tasks(quick=True) -> List[Task]:
    """'adult'-like tabular (RF in the paper -> MLP + RF here) and
    'mnist'-like digits (CNN)."""
    n_tab = 6000 if quick else 16000
    n_img = 4000 if quick else 12000
    parties_tab = 5 if quick else 20
    parties_img = 4 if quick else 10
    steps = 150 if quick else 400

    tab = tabular_binary(n=n_tab, seed=0)
    img = digits(n=n_img, image_size=16, seed=0)
    tasks = [
        Task("tabular", tab,
             NNLearner(MLP(tab["X_train"].shape[1], 2, hidden=32),
                       num_classes=2, steps=steps), 2, parties_tab,
             net=MLP(tab["X_train"].shape[1], 2, hidden=32)),
        Task("digits", img,
             NNLearner(PaperCNN(image_size=16, channels=1, num_classes=10),
                       num_classes=10, steps=steps), 10, parties_img,
             net=PaperCNN(image_size=16, channels=1, num_classes=10)),
    ]
    return tasks


def tree_task(quick=True) -> Task:
    """cod-rna-like binary task with the GBDT learner (model-agnostic
    demo: FedKT federates a non-differentiable model)."""
    tab = tabular_binary(n=4000 if quick else 12000, seed=1)
    return Task("tabular-gbdt", tab,
                GBDTLearner(num_rounds=10 if quick else 30, depth=4),
                2, 4 if quick else 10)


def fedcfg(task: Task, **kw) -> FedKTConfig:
    base = dict(num_parties=task.num_parties, num_partitions=2,
                num_subsets=3, num_classes=task.num_classes, beta=0.5,
                seed=0)
    base.update(kw)
    return FedKTConfig(**base)


class Emitter:
    def __init__(self):
        self.rows = []

    def emit(self, table, setting, metric, value):
        self.rows.append((table, setting, metric, value))
        print(f"{table},{setting},{metric},{value}")


def timed(fn, *a, **kw):
    t0 = time.time()
    out = fn(*a, **kw)
    return out, time.time() - t0
