"""Paper Figure 2: accuracy vs communication rounds; FedKT-Prox
initialization (paper §5.2)."""
from __future__ import annotations

from repro.core.baselines import IterConfig
from repro.core.partition import dirichlet_partition
from repro.federation import FedKTStrategy, IterativeStrategy

from benchmarks.common import Emitter, fedcfg, make_tasks


def run(em: Emitter, quick=True):
    task = make_tasks(quick)[1]          # digits (the paper plots MNIST)
    rounds = 10 if quick else 50
    cfg = fedcfg(task)
    parts = dirichlet_partition(task.data["y_train"], cfg.num_parties,
                                cfg.beta, cfg.seed)

    fk = FedKTStrategy(task.learner).run(
        task.data, cfg, party_indices=parts)
    em.emit("fig2", task.name, "FedKT-1round", round(fk.accuracy, 4))

    for algo in ("fedavg", "fedprox", "scaffold"):
        lr = 1e-2 if algo == "scaffold" else 1e-3
        out = IterativeStrategy(
            task.net, IterConfig(algo=algo, rounds=rounds, local_steps=60,
                                 lr=lr)).run(
            task.data, cfg, party_indices=parts)
        accs = out.meta["acc_per_round"]
        for r, acc in enumerate(accs, 1):
            em.emit("fig2", task.name, f"{algo}-r{r}", round(acc, 4))
        # rounds needed to beat FedKT
        beat = next((r + 1 for r, a in enumerate(accs)
                     if a > fk.accuracy), None)
        em.emit("fig2", task.name, f"{algo}-rounds-to-beat-FedKT",
                beat if beat else f">{rounds}")

    # FedKT-Prox: FedKT as initialization, then FedProx
    out = IterativeStrategy(
        task.net, IterConfig(algo="fedprox", rounds=rounds, local_steps=60,
                             lr=1e-3),
        init_params=fk.state).run(task.data, cfg, party_indices=parts)
    for r, acc in enumerate(out.meta["acc_per_round"], 1):
        em.emit("fig2", task.name, f"FedKT-Prox-r{r}", round(acc, 4))
