"""Paper Table 6: accuracy vs number of teacher subsets t."""
from repro.federation import FedKTSession
from benchmarks.common import Emitter, fedcfg, make_tasks


def run(em: Emitter, quick=True):
    task = make_tasks(quick)[0]
    for t in (3, 5, 10) if quick else (5, 10, 15):
        cfg = fedcfg(task, num_subsets=t)
        res = FedKTSession(task.learner, task.data, cfg).run()
        em.emit("table6", f"t={t}", "acc", round(res.accuracy, 4))
