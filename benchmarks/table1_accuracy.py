"""Paper Table 1: FedKT vs SOLO / PATE / central-GBDT / FedAvg / FedProx /
SCAFFOLD (2 rounds = equal communication, and many rounds).

Every compared algorithm is one ``repro.federation`` Strategy run
against the same data and party partition."""
from __future__ import annotations

from repro.core.baselines import IterConfig
from repro.core.partition import dirichlet_partition
from repro.federation import (CentralPATEStrategy, FedKTStrategy,
                              IterativeStrategy, SoloStrategy)

from benchmarks.common import Emitter, fedcfg, make_tasks, tree_task


def run(em: Emitter, quick=True):
    rounds_hi = 15 if quick else 50
    for task in make_tasks(quick):
        cfg = fedcfg(task)
        parts = dirichlet_partition(task.data["y_train"], cfg.num_parties,
                                    cfg.beta, cfg.seed)
        strategies = [FedKTStrategy(task.learner, name="FedKT"),
                      SoloStrategy(task.learner, name="SOLO"),
                      CentralPATEStrategy(task.learner, name="PATE")]
        for algo in ("fedavg", "fedprox", "scaffold"):
            for rounds, tag in ((2, "2r"), (rounds_hi, f"{rounds_hi}r")):
                lr = 1e-2 if algo == "scaffold" else 1e-3
                strategies.append(IterativeStrategy(
                    task.net,
                    IterConfig(algo=algo, rounds=rounds, local_steps=60,
                               lr=lr, mu=0.1),
                    label=f"{algo}-{tag}"))
        for strat in strategies:
            res = strat.run(task.data, cfg, party_indices=parts)
            em.emit("table1", task.name, strat.name,
                    round(res.accuracy, 4))

    # model-agnostic row: GBDT (non-differentiable - FedAvg cannot run it)
    t = tree_task(quick)
    cfg = fedcfg(t)
    res = FedKTStrategy(t.learner).run(t.data, cfg)
    em.emit("table1", t.name, "FedKT-GBDT", round(res.accuracy, 4))
    em.emit("table1", t.name, "SOLO-GBDT",
            round(SoloStrategy(t.learner).run(t.data, cfg).accuracy, 4))
    em.emit("table1", t.name, "CentralGBDT",
            round(_central(t), 4))


def _central(t):
    import jax
    from repro.core.learners import accuracy
    st = t.learner.fit(jax.random.PRNGKey(0), t.data["X_train"],
                       t.data["y_train"])
    return accuracy(t.learner, st, t.data["X_test"], t.data["y_test"])
