"""Paper Table 1: FedKT vs SOLO / PATE / central-GBDT / FedAvg / FedProx /
SCAFFOLD (2 rounds = equal communication, and many rounds)."""
from __future__ import annotations

from repro.core.baselines import IterConfig, run_iterative
from repro.core.fedkt import run_fedkt, run_pate_central, run_solo
from repro.core.learners import accuracy
from repro.core.partition import dirichlet_partition

from benchmarks.common import Emitter, fedcfg, make_tasks, tree_task


def run(em: Emitter, quick=True):
    rounds_hi = 15 if quick else 50
    for task in make_tasks(quick):
        cfg = fedcfg(task)
        parts = dirichlet_partition(task.data["y_train"], cfg.num_parties,
                                    cfg.beta, cfg.seed)
        res = run_fedkt(task.learner, task.data, cfg, party_indices=parts)
        em.emit("table1", task.name, "FedKT", round(res.accuracy, 4))
        em.emit("table1", task.name, "SOLO",
                round(run_solo(task.learner, task.data, cfg,
                               party_indices=parts), 4))
        em.emit("table1", task.name, "PATE",
                round(run_pate_central(task.learner, task.data, cfg), 4))
        for algo in ("fedavg", "fedprox", "scaffold"):
            for rounds, tag in ((2, "2r"), (rounds_hi, f"{rounds_hi}r")):
                lr = 1e-2 if algo == "scaffold" else 1e-3
                out = run_iterative(
                    task.net, task.data,
                    IterConfig(algo=algo, rounds=rounds, local_steps=60,
                               lr=lr, mu=0.1),
                    party_indices=parts)
                em.emit("table1", task.name, f"{algo}-{tag}",
                        round(out["acc_per_round"][-1], 4))

    # model-agnostic row: GBDT (non-differentiable - FedAvg cannot run it)
    t = tree_task(quick)
    cfg = fedcfg(t)
    res = run_fedkt(t.learner, t.data, cfg)
    em.emit("table1", t.name, "FedKT-GBDT", round(res.accuracy, 4))
    em.emit("table1", t.name, "SOLO-GBDT",
            round(run_solo(t.learner, t.data, cfg), 4))
    em.emit("table1", t.name, "CentralGBDT",
            round(_central(t), 4))


def _central(t):
    import jax
    from repro.core.learners import accuracy
    st = t.learner.fit(jax.random.PRNGKey(0), t.data["X_train"],
                       t.data["y_train"])
    return accuracy(t.learner, st, t.data["X_test"], t.data["y_test"])
