"""Serving-tier benchmark: continuous-batching throughput and latency.

Measures the ``repro.serving.Engine`` on the phi4-mini-3.8b smoke
config (float32, CPU) at N in {1, 4, 16} concurrent streams, plus the
fixed-batch ``serve_batch`` serial reference at the same token budget.
Written to BENCH_serving.json at the repo root:

  streams[N] : tok_per_s        — aggregate generated tokens / wall
               p50/p95_token_latency_ms — per-token gap distribution
                   across all streams (first token from admission)
               cold_s / warm_s  — same workload with compiles on the
                   clock (fresh engine, no warmup) vs after
                   ``Engine.warmup`` (zero recompiles, test-enforced)
  serial_reference : serve_batch stats at batch=4 for scale

Streams are submitted open-loop with seeded exponential gaps so later
arrivals land mid-decode — the continuous-batching case, not a batched
closed loop.  N > slots exercises queueing + slot reuse.

Tiny-config smoke: ``bench(tiny=True, write=False)`` runs the same
code on the 1-layer LM in seconds — invoked from tier-1 tests so this
script cannot rot.

    PYTHONPATH=src python -m benchmarks.serve_bench
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")
STREAMS = (1, 4, 16)
GEN = 32


def _percentile(xs, q):
    return sorted(xs)[min(len(xs) - 1, int(q * len(xs)))] if xs else 0.0


def _make_prompts(cfg, n, max_len, seed):
    rng = np.random.default_rng(seed)
    lens = rng.integers(2, max_len + 1, n)
    return [rng.integers(0, cfg.vocab_size, (int(p),)).astype(np.int32)
            for p in lens]


def _drive_open_loop(eng, prompts, gen, rate, seed):
    """Seeded Poisson arrivals at ``rate`` req/s; returns (results,
    wall seconds)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, len(prompts))
    t0 = eng.clock()
    deadlines = list(zip(t0 + np.cumsum(gaps), prompts))
    results = []
    while deadlines or not eng.scheduler.idle:
        now = eng.clock()
        while deadlines and deadlines[0][0] <= now:
            eng.submit(deadlines.pop(0)[1], gen)
        if eng.scheduler.idle and deadlines:
            time.sleep(min(max(deadlines[0][0] - now, 0.0), 0.005))
            continue
        results.extend(eng.step())
    return results, eng.clock() - t0


def _run_once(model, params, prompts, gen, slots, cache_len, rate,
              seed, warm):
    from repro.serving import Engine
    eng = Engine(model, params, num_slots=slots, cache_len=cache_len)
    if warm:
        eng.warmup(buckets=[p.shape[0] for p in prompts])
    t0 = eng.clock()
    results, _ = _drive_open_loop(eng, prompts, gen, rate, seed)
    wall = eng.clock() - t0
    assert len(results) == len(prompts)
    return results, wall, eng.compile_counts()


def bench(tiny=False, write=True):
    import jax
    from repro.models import Model
    from repro.serving import serve_batch

    if tiny:
        from repro.configs.base import ModelConfig
        cfg = ModelConfig(name="tiny-lm", num_layers=1, d_model=32,
                          num_heads=2, num_kv_heads=2, d_ff=64,
                          vocab_size=64, dtype="float32",
                          param_dtype="float32")
        streams, gen, slots, cache_len, max_len = (1, 2), 6, 2, 64, 12
    else:
        from repro.configs import get_smoke
        cfg = get_smoke("phi4-mini-3.8b").replace(
            dtype="float32", param_dtype="float32")
        streams, gen, slots, cache_len, max_len = STREAMS, GEN, 4, 256, 64
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rows = {}
    for n in streams:
        prompts = _make_prompts(cfg, n, max_len, seed=n)
        rate = max(2.0 * n, 4.0)       # arrivals overlap decode
        # cold: compiles on the clock (deploy-restart worst case)
        _, cold, _ = _run_once(model, params, prompts, gen, slots,
                               cache_len, rate, n, warm=False)
        # warm: after warmup; the steady-state numbers that matter
        results, warm, counts = _run_once(model, params, prompts, gen,
                                          slots, cache_len, rate, n,
                                          warm=True)
        toks = sum(r.num_tokens for r in results)
        lats = [t for r in results
                for t in r.timing["token_latencies"]]
        rows[str(n)] = {
            "tok_per_s": round(toks / max(warm, 1e-9), 2),
            "p50_token_latency_ms": round(
                _percentile(lats, 0.5) * 1e3, 3),
            "p95_token_latency_ms": round(
                _percentile(lats, 0.95) * 1e3, 3),
            "cold_s": round(cold, 3),
            "warm_s": round(warm, 3),
            "tokens": toks,
            "compile_counts": counts,
        }

    # serial fixed-batch reference at the middle stream count's budget
    B = min(4, max(streams))
    rng = np.random.default_rng(0)
    batch = rng.integers(0, cfg.vocab_size, (B, max_len)).astype(np.int32)
    serve_batch(model, params, batch, gen, verbose=False)   # compile
    _, sstats = serve_batch(model, params, batch, gen, verbose=False)

    rec = {
        "arch": cfg.name,
        "shape": {"slots": slots, "cache_len": cache_len, "gen": gen,
                  "max_prompt": max_len, "dtype": cfg.dtype},
        "streams": rows,
        "serial_reference": {
            "batch": B, "prompt_len": max_len,
            "tok_per_s": round(sstats["tok_per_s"], 2),
            "decode_s": round(sstats["decode_s"], 3)},
    }
    if write:
        with open(OUT, "w") as fh:
            json.dump(rec, fh, indent=1)
            fh.write("\n")
    return rec


def run(em, quick=True):
    """benchmarks.run entry: quick mode never overwrites the committed
    BENCH record."""
    rec = bench(tiny=quick, write=not quick)
    for n, row in rec["streams"].items():
        em.emit("serving", f"streams{n}", "tok_per_s", row["tok_per_s"])
        em.emit("serving", f"streams{n}", "p50_ms",
                row["p50_token_latency_ms"])
        em.emit("serving", f"streams{n}", "p95_ms",
                row["p95_token_latency_ms"])
    em.emit("serving", "serial_reference", "tok_per_s",
            rec["serial_reference"]["tok_per_s"])


if __name__ == "__main__":
    print(json.dumps(bench(), indent=1))
