"""Sharded LM students through the session driver, locked down by
parity: FedKTSession + LMLearner must reproduce a direct transcription
of the distill.py loop (Algorithm 1 on make_label_step/make_train_step)
seed-for-seed — labels, gaps, student/final states and final loss — in
BOTH the serial ``loop`` engine and the fused ``lm`` engine.  Plus the
wire side: codec round-trip property tests for LM-shaped messages and
framed-bytes parity for the dry-run's protocol pricing.

The reference here is the CANONICAL direct loop (the protocol's
``subsets_of_partition`` plan, per-fit shuffle streams, the session's
key schedule) — deliberately NOT the deleted ``fedkt_lm``'s ad-hoc
subset scheme and shared-rng batch stream, whose exact numbers are not
preserved (see the ``fedkt_lm`` docstring in launch/train.py)."""
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke_model, tiny_lm_config
from hypothesis_compat import given, settings, st
from repro.configs.base import FedKTConfig, TrainConfig
from repro.core.distill import make_label_step, make_train_step
from repro.core.partition import dirichlet_partition, subsets_of_partition
from repro.core.learners import LMLearner
from repro.core.voting import consistent_vote
from repro.data import TokenDataset, lm_session_data, synthetic
from repro.federation import (FedKTSession, LMEngine, PartyUpdate,
                              TokenLabels, codec, get_engine,
                              query_budget)
from repro.federation.party import Party
from repro.models import Model


def _tree_equal(a, b):
    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(leaves_a) == len(leaves_b)
    for la, lb in zip(leaves_a, leaves_b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# The legacy direct loop: Algorithm 1 transcribed onto the raw
# distill.py steps.  This is the reference the session must reproduce.
# ---------------------------------------------------------------------------
def _direct_fedkt_lm(model, tcfg, fcfg, train, public):
    """Hand-rolled LM FedKT on make_label_step/make_train_step with the
    canonical partition plan and the serial key schedule."""
    step, opt = make_train_step(model, tcfg)
    step = jax.jit(step)

    def fit(seqs, data_seed, labels=None):
        params = model.init(jax.random.PRNGKey(tcfg.seed))
        opt_state = opt.init(params)
        for batch in TokenDataset(seqs, data_seed).batches(
                tcfg.batch_size, steps=tcfg.steps, labels=labels):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, _ = step(params, opt_state, batch)
        return params

    s, t = fcfg.num_partitions, fcfg.num_subsets
    proxy = (train[:, 0] % 10).astype(np.int32)
    parts = dirichlet_partition(proxy, fcfg.num_parties, fcfg.beta,
                                fcfg.seed)
    tq_party, tq_server = query_budget(fcfg, len(public))
    Xq = public[:tq_party]
    toks_q = jnp.asarray(Xq[:, :-1])
    gamma_p = fcfg.gamma if fcfg.privacy_level == "L2" else 0.0
    label_step = jax.jit(make_label_step(model, t, gamma=gamma_p))

    key = jax.random.PRNGKey(fcfg.seed)
    students, labelsets, gaps = [], [], []
    for i, ix in enumerate(parts):
        plan = subsets_of_partition(ix, s, t, seed=fcfg.seed + 17 * i)
        students_i, gaps_i = [], []
        for j in range(s):
            for _ in range(t):                     # teacher keys (the LM
                key, _ = jax.random.split(key)     # fits seed from tcfg)
            key, vote_key = jax.random.split(key)
            key, _ = jax.random.split(key)         # student key (unused)
            members = [fit(train[sub], 0) for sub in plan[j]]
            bank = jax.tree.map(lambda *xs: jnp.stack(xs), *members)
            labels, gap = label_step(bank, {"tokens": toks_q}, vote_key)
            students_i.append(fit(Xq, fcfg.seed,
                                  labels=np.asarray(labels)))
            labelsets.append(np.asarray(labels).reshape(-1))
            gaps_i.append(np.asarray(gap).reshape(-1))
        students.append(students_i)
        gaps.append(np.concatenate(gaps_i))

    Xq_srv = public[:tq_server]
    toks_srv = jnp.asarray(Xq_srv[:, :-1])
    preds = jnp.stack([
        jnp.stack([model.predict(sp, {"tokens": toks_srv}).reshape(-1)
                   for sp in si]) for si in students])       # (n, s, T)
    key, kk = jax.random.split(key)
    vote = consistent_vote(
        preds, fcfg.num_classes, consistent=fcfg.consistent_voting,
        gamma=fcfg.gamma if fcfg.privacy_level == "L1" else 0.0, key=kk)
    key, _ = jax.random.split(key)                 # final-fit key (unused)
    final = fit(Xq_srv, fcfg.seed,
                labels=np.asarray(vote.labels).reshape(len(Xq_srv), -1))
    return {"students": students, "final": final, "gaps": gaps,
            "labels": labelsets}


FCFG = dict(num_parties=2, num_partitions=2, num_subsets=2,
            num_classes=64, beta=100.0, seed=0)


@pytest.fixture(scope="module")
def lm_setup(tiny_lm):
    cfg, model = tiny_lm
    tcfg = TrainConfig(batch_size=4, seq_len=16, steps=4,
                       learning_rate=3e-3)
    data = synthetic.tokens(n_seqs=64, seq_len=17, vocab=cfg.vocab_size,
                            seed=0)
    return {"cfg": cfg, "model": model, "tcfg": tcfg, "tokens": data,
            "teacher": LMLearner(model, tcfg),
            "student": LMLearner(model, tcfg, data_seed=FCFG["seed"])}


@pytest.fixture(scope="module")
def direct_reference(lm_setup):
    fcfg = FedKTConfig(**FCFG)
    return _direct_fedkt_lm(lm_setup["model"], lm_setup["tcfg"], fcfg,
                            lm_setup["tokens"]["train"],
                            lm_setup["tokens"]["public"])


def _run_session(lm_setup, fcfg, engine, **kw):
    d = lm_setup["tokens"]
    data = lm_session_data(d["train"], d["public"], d["test"])
    return FedKTSession(lm_setup["teacher"], data, fcfg,
                        student_learner=lm_setup["student"],
                        final_learner=lm_setup["student"], engine=engine,
                        **kw).run()


# ---------------------------------------------------------------------------
# Parity: session == direct loop, loop and lm engines
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["loop", "lm"])
def test_session_lm_matches_direct_loop(lm_setup, direct_reference,
                                        engine):
    """THE acceptance test: FedKTSession drives LM distillation
    end-to-end and its states are bit-identical to the hand-rolled
    distill.py loop, under both the serial and the fused-label-step
    engines."""
    res = _run_session(lm_setup, FedKTConfig(**FCFG), engine)
    _tree_equal(res.student_states, direct_reference["students"])
    _tree_equal(res.final_state, direct_reference["final"])
    assert res.epsilon is None                       # L0
    assert 0.0 <= res.accuracy <= 1.0


@pytest.mark.parametrize("engine", ["loop", "lm"])
def test_party_lm_labels_and_gaps_match_direct(lm_setup,
                                               direct_reference, engine):
    """Party-level: the PartyUpdate's vote-gap trace and the voted
    labels match the direct loop exactly (party 0, both engines)."""
    d, fcfg = lm_setup["tokens"], FedKTConfig(**FCFG)
    data = lm_session_data(d["train"], d["public"], d["test"])
    parts = dirichlet_partition(data["y_train"], fcfg.num_parties,
                                fcfg.beta, fcfg.seed)
    party = Party(party_id=0, X=data["X_train"], y=data["y_train"],
                  indices=parts[0], cfg=fcfg, learner=lm_setup["teacher"],
                  student_learner=lm_setup["student"])
    upd, _ = party.local_round(jax.random.PRNGKey(fcfg.seed),
                               data["X_public"], len(data["X_public"]),
                               get_engine(engine))
    np.testing.assert_array_equal(upd.vote_gaps,
                                  direct_reference["gaps"][0])
    _tree_equal(upd.student_states, direct_reference["students"][0])
    T = (d["public"].shape[1] - 1) * len(d["public"])
    assert upd.meta["num_query_labels"] == T
    assert upd.meta["label_payload_bytes"] == T * 4


def test_final_student_loss_matches_direct(lm_setup, direct_reference):
    """The distilled final model's test loss is the same number through
    the session as through the direct loop (states are bit-equal, so
    the loss must be too — this pins the claim end-to-end)."""
    model, d = lm_setup["model"], lm_setup["tokens"]
    res = _run_session(lm_setup, FedKTConfig(**FCFG), "lm")
    batch = {"tokens": jnp.asarray(d["test"][:, :-1]),
             "labels": jnp.asarray(d["test"][:, 1:])}
    loss_session = float(model.loss(res.final_state, batch, remat=False))
    loss_direct = float(model.loss(direct_reference["final"], batch,
                                   remat=False))
    assert np.isfinite(loss_session)
    assert loss_session == loss_direct


def test_lm_engines_agree_under_l2_noise(lm_setup):
    """Under FedKT-L2 the vote is noised and the accountant consumes the
    CLEAN gap: loop and lm engines must still produce identical labels
    (same key -> same Laplace draw), identical clean gaps, and the same
    epsilon."""
    fcfg = FedKTConfig(**{**FCFG, "privacy_level": "L2", "gamma": 0.05,
                          "query_fraction": 0.5})
    r_loop = _run_session(lm_setup, fcfg, "loop")
    r_lm = _run_session(lm_setup, fcfg, "lm")
    assert r_loop.epsilon == r_lm.epsilon > 0
    assert r_loop.accuracy == r_lm.accuracy
    _tree_equal(r_loop.student_states, r_lm.student_states)
    _tree_equal(r_loop.final_state, r_lm.final_state)


def test_lm_thread_transport_matches_inprocess(lm_setup):
    """LM parties fan out over the thread transport bit-identically
    (precomputed keys + stateless learners, like every other mode)."""
    fcfg = FedKTConfig(**FCFG)
    ref = _run_session(lm_setup, fcfg, "lm")
    par = _run_session(lm_setup, fcfg, "lm", transport="thread",
                       parallelism=2)
    assert par.accuracy == ref.accuracy
    _tree_equal(par.student_states, ref.student_states)
    assert par.meta["wire_bytes"] == ref.meta["wire_bytes"]


def test_session_wire_meta_counts_tokens(lm_setup):
    """Label accounting counts TOKENS on the LM path: raw payload is
    n_parties * T * 4 bytes and the framed size (measured codec framing)
    is strictly larger by only the header."""
    res = _run_session(lm_setup, FedKTConfig(**FCFG), "lm")
    d = lm_setup["tokens"]
    T = (d["public"].shape[1] - 1) * len(d["public"])
    wb = res.meta["wire_bytes"]
    assert wb["labels"] == FCFG["num_parties"] * T * 4
    assert wb["labels"] < wb["labels_framed"] < wb["labels"] + 4096
    assert wb["updates"] > wb["updates_payload"] > 0


def test_lm_learner_pickles_after_use(lm_setup):
    """Subprocess transports pickle parties (learners included); the
    jitted-step caches must be dropped, not shipped."""
    lrn = LMLearner(lm_setup["model"], lm_setup["tcfg"])
    X = lm_setup["tokens"]["public"]
    p1 = lrn.predict(lrn.fit(jax.random.PRNGKey(0), X), X)
    clone = pickle.loads(pickle.dumps(lrn))        # caches populated
    assert clone.tcfg == lrn.tcfg and clone.data_seed == lrn.data_seed
    p2 = clone.predict(clone.fit(jax.random.PRNGKey(0), X), X)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_engine_registry_includes_lm():
    assert get_engine("lm").name == "lm"
    eng = LMEngine()
    assert get_engine(eng) is eng
    with pytest.raises(TypeError):
        eng.fit_teachers([], object(), [])         # generic learner
    with pytest.raises(ValueError):
        lrn = LMLearner(Model(tiny_lm_config()), TrainConfig())
        eng.label_queries(lrn, None, None, 10)     # num_classes != vocab


# ---------------------------------------------------------------------------
# Full-size variant: the example's phi4-family smoke config
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_session_lm_matches_direct_loop_full_size():
    """Seed-for-seed parity at the example's scale (reduced phi4 config,
    512-token vocab, bf16 activations) — scheduled full run only."""
    cfg, model = smoke_model("phi4-mini-3.8b", vocab_size=512)
    tcfg = TrainConfig(batch_size=8, seq_len=64, steps=10,
                       learning_rate=3e-3)
    fcfg = FedKTConfig(num_parties=2, num_partitions=2, num_subsets=2,
                       num_classes=cfg.vocab_size, beta=100.0, seed=0)
    data = synthetic.tokens(n_seqs=192, seq_len=65, vocab=cfg.vocab_size,
                            seed=0)
    direct = _direct_fedkt_lm(model, tcfg, fcfg, data["train"],
                              data["public"])
    teacher = LMLearner(model, tcfg)
    student = LMLearner(model, tcfg, data_seed=fcfg.seed)
    sdata = lm_session_data(data["train"], data["public"], data["test"])
    for engine in ("loop", "lm"):
        res = FedKTSession(teacher, sdata, fcfg, student_learner=student,
                           final_learner=student, engine=engine).run()
        _tree_equal(res.student_states, direct["students"])
        _tree_equal(res.final_state, direct["final"])


# ---------------------------------------------------------------------------
# Wire: codec round-trips for LM-shaped messages, framed-bytes parity
# ---------------------------------------------------------------------------
def _lm_update(rng, members, s, B, S, d=8):
    """An LM-shaped PartyUpdate: member-stacked param trees (mixed f32 /
    bf16), f32 vote-gap trace over s partitions of B*S tokens."""
    def member_tree():
        return {"embed": rng.normal(size=(members, 16, d))
                .astype(np.float32),
                "blocks": [{"w": jnp.asarray(
                    rng.normal(size=(members, d, d)), jnp.bfloat16)}],
                "step": np.int32(rng.integers(0, 100))}
    return PartyUpdate(
        party_id=int(rng.integers(0, 8)),
        student_states=[member_tree() for _ in range(s)],
        vote_gaps=rng.random(s * B * S).astype(np.float32),
        num_examples=int(rng.integers(1, 1000)),
        meta={"num_teachers": members,
              "num_query_labels": int(B * S)})


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(1, 3))
def test_codec_roundtrip_lm_update_property(seed, members, s):
    """encode∘decode identity and exact framed-size accounting for
    member-stacked LM PartyUpdates."""
    rng = np.random.default_rng(seed)
    B, S = int(rng.integers(1, 4)), int(rng.integers(2, 9))
    upd = _lm_update(rng, members, s, B, S)
    buf = codec.encode_update(upd)
    assert codec.update_encoded_nbytes(upd) == len(buf)
    dec = codec.decode_update(buf)
    assert dec.party_id == upd.party_id
    assert dec.num_examples == upd.num_examples
    assert dec.meta == upd.meta
    assert dec.wire_bytes() == upd.wire_bytes()
    _tree_equal(upd.student_states, dec.student_states)
    np.testing.assert_array_equal(upd.vote_gaps, dec.vote_gaps)
    assert dec.student_states[0]["blocks"][0]["w"].dtype == jnp.bfloat16


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.booleans())
def test_codec_roundtrip_token_labels_property(seed, token_shaped):
    """The TokenLabels vote-answer kind round-trips bit-for-bit — (B,S)
    int32 token labels and flat (T,) class labels alike — and
    labels_encoded_nbytes is the measured framed size."""
    rng = np.random.default_rng(seed)
    shape = ((int(rng.integers(1, 5)), int(rng.integers(1, 17)))
             if token_shaped else (int(rng.integers(1, 65)),))
    msg = TokenLabels(party_id=int(rng.integers(0, 8)),
                      labels=rng.integers(0, 512, shape, dtype=np.int32),
                      meta={"partition": 1})
    buf = codec.encode_labels(msg)
    assert codec.labels_encoded_nbytes(msg) == len(buf)
    dec = codec.decode_labels(buf)
    assert dec.party_id == msg.party_id and dec.meta == msg.meta
    assert dec.labels.dtype == np.int32 and dec.labels.shape == shape
    np.testing.assert_array_equal(dec.labels, msg.labels)
    assert dec.wire_bytes() == msg.wire_bytes() == msg.labels.nbytes
    with pytest.raises(ValueError):
        codec.decode_labels(codec.encode({"w": np.zeros(1)}))


def test_lm_protocol_pricing_matches_measured_bytes():
    """Acceptance: the dry-run's priced LM wire bytes (computed from
    eval_shape trees, no arrays materialized) equal the codec's measured
    framed bytes of the REAL messages, bit-for-bit."""
    members, B, S = 3, 2, 16
    member = {"embed": np.zeros((64, 8), np.float32),
              "out": {"w": jnp.zeros((8, 64), jnp.bfloat16)}}
    priced = codec.lm_protocol_bytes(
        jax.eval_shape(lambda: member), members, B, S)
    upd = PartyUpdate(party_id=0, student_states=[member],
                      vote_gaps=np.zeros((B * S,), np.float32),
                      num_examples=0, meta={"num_teachers": members})
    lbl = TokenLabels(party_id=0,
                      labels=np.zeros((B, S), np.int32))
    assert priced["update_bytes_per_member"] == len(codec.encode_update(upd))
    assert priced["update_payload_bytes_per_member"] == upd.wire_bytes()
    assert priced["label_bytes"] == len(codec.encode_labels(lbl))
    assert priced["label_payload_bytes"] == B * S * 4
    assert priced["members"] == members
