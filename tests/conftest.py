# NOTE: no XLA_FLAGS here — smoke tests must see the real (single) CPU
# device; only launch/dryrun.py requests 512 placeholder devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root too: benchmark smoke tests import the benchmarks package
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np  # noqa: E402
import pytest  # noqa: E402

# ---------------------------------------------------------------------------
# Shared LM model/config helpers (test_distill_lm, test_archs,
# test_federation_lm) — one place for the tiny-transformer setup the LM
# tests kept rebuilding.
# ---------------------------------------------------------------------------


def tiny_lm_config(**overrides):
    """The smallest runnable decoder config: float32 for determinism,
    one attention layer, 64-token vocab.  Fast enough for tier-1
    parity runs (full-size variants use ``smoke_model`` instead)."""
    from repro.configs.base import ModelConfig
    kw = dict(name="tiny-lm", num_layers=1, d_model=32, num_heads=2,
              num_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
              param_dtype="float32")
    kw.update(overrides)
    return ModelConfig(**kw)


def smoke_model(arch, **overrides):
    """(cfg, Model) for a registry arch's reduced SMOKE variant, with
    optional config overrides (vocab_size=..., dtype=..., ...)."""
    from repro.configs import get_smoke
    from repro.models import Model
    cfg = get_smoke(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg, Model(cfg)


def lm_batch(cfg, B=2, S=32, seed=0):
    """Random {tokens, labels} batch for ``cfg`` (plus the encoder-frame /
    VLM-embed extras the multimodal archs expect)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if cfg.is_encoder_decoder:
        b["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.encoder_seq_len, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    elif cfg.frontend_embeds:
        b["embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.frontend_embeds, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    return b


@pytest.fixture(scope="session")
def tiny_lm():
    """Shared tiny transformer: (cfg, Model) — session-scoped so every
    LM test reuses one jit cache."""
    from repro.models import Model
    cfg = tiny_lm_config()
    return cfg, Model(cfg)
