# NOTE: no XLA_FLAGS here — smoke tests must see the real (single) CPU
# device; only launch/dryrun.py requests 512 placeholder devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root too: benchmark smoke tests import the benchmarks package
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
