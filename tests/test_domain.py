"""VoteDomain: the typed vote-layout contract — identity/wire
round-trips, mixed per-token + per-example rounds (two independent
histograms in one socket session, arrival-order independent and
bit-identical to the single-domain folds), same-unit clash refusal
naming both parties, ACK-time domain validation at the coordinator,
and the vertically-partitioned scenario (feature-split silos over real
TCP — the tiny-config smoke of examples/vertical_fedkt.py)."""
import argparse

import jax
import numpy as np
import pytest

from repro.configs.base import FedKTConfig, TrainConfig
from repro.core.learners import (GBDTLearner, LMLearner, NNLearner,
                                 RFLearner)
from repro.core.partition import vertical_split
from repro.data import synthetic
from repro.data.synthetic import tabular_binary
from repro.federation import (FedKTSession, PartyBinding, SocketTransport,
                              VoteDomain, party_starting_keys)
from repro.federation.domain import (check_same_unit, example_domain,
                                     fingerprint_queries, learner_domain,
                                     token_domain)
from repro.federation.codec import decode_update, encode_update
from repro.federation.engines import LoopEngine
from repro.federation.messages import PartyUpdate
from repro.federation.net import Coordinator, send_update_frame
from repro.federation.party import Party
from repro.federation.server import Server
from repro.launch import federate
from repro.models.smallnets import MLP


def _wire_trip(upd):
    """What every transport does: encode, decode, annotate the measured
    frame size (the aggregate's wire accounting reads it)."""
    buf = encode_update(upd)
    out = decode_update(buf)
    out.meta["encoded_bytes"] = len(buf)
    return out


def _tree_equal(a, b):
    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(leaves_a) == len(leaves_b)
    for la, lb in zip(leaves_a, leaves_b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# The domain type itself
# ---------------------------------------------------------------------------
def test_domain_identity_and_matching():
    a = VoteDomain("example", 16, 2, fingerprint="abcd")
    assert a.key == ("example", 16, 2, "abcd")
    assert "example" in a.ident and "T16" in a.ident and "U2" in a.ident
    # full agreement matches; anonymous fingerprint is a wildcard
    assert a.matches(VoteDomain("example", 16, 2, fingerprint="abcd"))
    assert a.matches(VoteDomain("example", 16, 2))           # anon wire
    assert VoteDomain("example", 16, 2).matches(a)
    # any layout field breaks the match
    assert not a.matches(VoteDomain("example", 16, 2, fingerprint="ffff"))
    assert not a.matches(VoteDomain("example", 17, 2, fingerprint="abcd"))
    assert not a.matches(VoteDomain("example", 16, 3, fingerprint="abcd"))
    assert not a.matches(VoteDomain("token", 16, 2, fingerprint="abcd"))
    # label_names is a descriptive tag, never identity
    tagged = VoteDomain("example", 16, 2, fingerprint="abcd",
                        label_names=("no", "yes"))
    assert tagged == a and tagged.key == a.key


def test_domain_validation_and_wire_roundtrip():
    with pytest.raises(ValueError, match="unknown vote unit"):
        VoteDomain("pixel", 4, 2)
    with pytest.raises(ValueError, match="degenerate"):
        VoteDomain("example", 0, 2)
    assert VoteDomain.from_wire(None) is None
    for dom in (VoteDomain("token", 768, 64, fingerprint="00ff"),
                VoteDomain("example", 5, 3),
                VoteDomain("example", 5, 3, label_names=("a", "b", "c"))):
        back = VoteDomain.from_wire(dom.to_wire())
        assert back == dom and back.key == dom.key
        assert back.label_names == dom.label_names
    inferred = VoteDomain.infer_legacy((12, 4))
    assert inferred.key == ("example", 12, 4, None)


def test_fingerprint_distinguishes_content_not_just_shape():
    X = np.arange(12, dtype=np.float32).reshape(4, 3)
    fp = fingerprint_queries(X)
    assert fp == fingerprint_queries(X.copy())
    Y = X.copy()
    Y[0, 0] += 1
    assert fp != fingerprint_queries(Y)
    assert fp != fingerprint_queries(X.astype(np.float64))


def test_learner_domain_derivation():
    Xq = np.zeros((8, 14), np.float32)
    nn = NNLearner(MLP(14, 2, hidden=8), num_classes=2, steps=5)
    dom = learner_domain(nn, Xq, 10)
    # the learner's OWN class count wins over the session default
    assert dom.key[:3] == ("example", 8, 2)
    assert dom.fingerprint == fingerprint_queries(Xq)

    class Bare:                       # no num_classes field
        pass
    assert learner_domain(Bare(), Xq, 10).num_classes == 10
    assert example_domain(Xq, 2).unit == "example"
    assert token_domain(128, 64).key == ("token", 128, 64, None)


def test_check_same_unit_names_both_parties():
    ex = VoteDomain("example", 16, 2)
    tok = VoteDomain("token", 256, 64)
    check_same_unit(ex, tok, party_a=0, party_b=1)   # coexist: no raise
    with pytest.raises(ValueError,
                       match=r"(?s)clash.*party 0.*party 3"):
        check_same_unit(ex, VoteDomain("example", 16, 3),
                        party_a=0, party_b=3)


# ---------------------------------------------------------------------------
# Mixed per-token + per-example rounds
# ---------------------------------------------------------------------------
MIXED_FCFG = dict(num_parties=2, num_partitions=1, num_subsets=2,
                  num_classes=2, beta=100.0, seed=0)


@pytest.fixture(scope="module")
def mixed_setup(tiny_lm):
    """One lm silo + one nn silo over SHARED token sequences: the LM
    reads them as (N, S+1) token matrices, the MLP as S+1 numeric
    features — same X, two vote units."""
    cfg, model = tiny_lm
    tcfg = TrainConfig(batch_size=4, seq_len=16, steps=2,
                       learning_rate=3e-3)
    toks = synthetic.tokens(n_seqs=32, seq_len=17, vocab=cfg.vocab_size,
                            seed=0)
    data = {"X_train": toks["train"].astype(np.float32),
            "y_train": (toks["train"][:, 0] % 2).astype(np.int32),
            "X_public": toks["public"].astype(np.float32),
            "X_test": toks["test"].astype(np.float32),
            "y_test": (toks["test"][:, 0] % 2).astype(np.int32)}
    nfeat = data["X_train"].shape[1]
    lm = LMLearner(model, tcfg, data_seed=MIXED_FCFG["seed"])
    nn = NNLearner(MLP(nfeat, 2, hidden=8), num_classes=2, steps=10)
    bindings = [PartyBinding(lm, engine="lm"), PartyBinding(nn)]
    return {"data": data, "bindings": bindings, "nn": nn, "lm": lm,
            "vocab": cfg.vocab_size}


def _mixed_session(mixed_setup, **kw):
    cfg = FedKTConfig(**MIXED_FCFG)
    return FedKTSession(mixed_setup["bindings"], mixed_setup["data"], cfg,
                        final_learner=mixed_setup["nn"], **kw)


def test_mixed_domain_socket_session(mixed_setup):
    """Acceptance: one lm (per-token) + one nn (per-example) party in a
    SOCKET session complete with two independent per-domain
    VoteResults, each with its own labels and its own epsilon fold."""
    res = _mixed_session(
        mixed_setup, transport=SocketTransport(parallelism=2)).run()
    assert len(res.by_domain) == 2
    units = sorted(d["vote"].domain.unit for d in res.by_domain.values())
    assert units == ["example", "token"]
    Npub = len(mixed_setup["data"]["X_public"])
    S = mixed_setup["data"]["X_public"].shape[1] - 1
    for ident, row in res.by_domain.items():
        dom = row["vote"].domain
        assert ident == dom.ident
        T = Npub * S if dom.unit == "token" else Npub
        assert row["labels"].shape == (T,)
        assert np.asarray(row["vote"].counts).shape == \
            (T, dom.num_classes)
        assert row["epsilon"] is None                      # L0
        assert len(row["parties"]) == 1
    # wire accounting breaks down per domain too
    by_dom = res.meta["wire_bytes"]["by_domain"]
    assert set(by_dom) == set(res.by_domain)
    assert all(v > 0 for v in by_dom.values())
    assert 0.0 <= res.accuracy <= 1.0


def test_mixed_domains_match_single_domain_folds_any_order(mixed_setup):
    """Each domain's VoteResult in the mixed round is bit-identical to
    the single-domain fold of just that party — in either arrival
    order (integer folds commute; domains never share a histogram)."""
    cfg = FedKTConfig(**MIXED_FCFG)
    session = _mixed_session(mixed_setup)
    keys, _ = party_starting_keys(session.parties, cfg.seed)
    updates = [_wire_trip(p.local_round(k, session.data["X_public"],
                                        session.tq_party)[0])
               for p, k in zip(session.parties, keys)]
    fkey = jax.random.PRNGKey(99)

    def fold(order, only=None):
        agg = session.server.make_aggregate(session.data["X_public"],
                                            session.tq_server,
                                            session.engine)
        for i in order:
            if only is None or i in only:
                agg.add(updates[i])
        return agg

    # single-domain references: one aggregate per party
    singles = {}
    for i, upd in enumerate(updates):
        agg_i = fold([i], only={i})
        (dom,) = agg_i.domains()
        singles[dom.ident] = agg_i.finalize_domain(dom, fkey)

    for order in ([0, 1], [1, 0]):
        agg = fold(order)
        assert len(agg.domains()) == 2
        for dom in agg.domains():
            vote = agg.finalize_domain(dom, fkey)
            ref = singles[dom.ident]
            np.testing.assert_array_equal(np.asarray(vote.counts),
                                          np.asarray(ref.counts))
            np.testing.assert_array_equal(np.asarray(vote.labels),
                                          np.asarray(ref.labels))
            assert vote.domain == ref.domain


def test_mixed_socket_session_order_independent(mixed_setup):
    """The full socket session twice: per-domain labels and counts are
    identical run-to-run even though TCP arrival order is arbitrary."""
    r1 = _mixed_session(
        mixed_setup, transport=SocketTransport(parallelism=2)).run()
    r2 = _mixed_session(
        mixed_setup, transport=SocketTransport(parallelism=1)).run()
    assert set(r1.by_domain) == set(r2.by_domain)
    for ident in r1.by_domain:
        np.testing.assert_array_equal(r1.by_domain[ident]["labels"],
                                      r2.by_domain[ident]["labels"])
        np.testing.assert_array_equal(
            np.asarray(r1.by_domain[ident]["vote"].counts),
            np.asarray(r2.by_domain[ident]["vote"].counts))
    _tree_equal(r1.final_state, r2.final_state)
    assert r1.accuracy == r2.accuracy


def test_same_unit_class_clash_refused_naming_both_parties():
    """Two example-unit parties with different class spaces cannot share
    a histogram: the fold refuses the second update, naming both
    parties and both domains."""
    data = tabular_binary(n=256, seed=0)
    cfg = FedKTConfig(num_parties=2, num_partitions=1, num_subsets=2,
                      num_classes=2, seed=0)
    b0 = PartyBinding(NNLearner(MLP(14, 2, hidden=8), num_classes=2,
                                steps=5)).resolve()
    b1 = PartyBinding(NNLearner(MLP(14, 3, hidden=8), num_classes=3,
                                steps=5)).resolve()
    idx = np.arange(len(data["X_train"]))
    parties = [Party(party_id=i, X=data["X_train"], y=data["y_train"],
                     indices=idx, cfg=cfg, learner=b.learner,
                     student_learner=b.student_learner, engine=b.engine)
               for i, b in enumerate([b0, b1])]
    server = Server(cfg, b0.student_learner, b0.student_learner,
                    bindings={0: b0, 1: b1})
    agg = server.make_aggregate(data["X_public"],
                                len(data["X_public"]), LoopEngine())
    key = jax.random.PRNGKey(0)
    for p in parties:
        raw, key = p.local_round(key, data["X_public"],
                                 len(data["X_public"]))
        upd = _wire_trip(raw)
        if p.party_id == 0:
            agg.add(upd)
        else:
            with pytest.raises(ValueError,
                               match=r"(?s)party 0.*party 1"):
                agg.add(upd)


def test_coordinator_naks_domain_mismatch_at_ack_time():
    """A party whose declared domain contradicts what the session
    expects is NAKed at DELIVERY — the server never folds (or trains
    over) the update, and the rejection is recorded."""
    upd = PartyUpdate(
        party_id=0,
        student_states=[{"w": np.zeros((2, 2), np.float32)}],
        vote_gaps=np.zeros((4,), np.float32), num_examples=4,
        learner_kind="nn",
        domain=VoteDomain("example", 8, 2, fingerprint="aaaa"),
        meta={"num_teachers": 1, "num_query_labels": 8})
    expected = {0: VoteDomain("token", 128, 64, fingerprint="bbbb")}
    coord = Coordinator([0], expected_domains=expected).start()
    try:
        with pytest.raises(ConnectionError, match="NAK"):
            send_update_frame("127.0.0.1", coord.port,
                              encode_update(upd), retries=1)
        assert any("vote-domain mismatch" in e for e in coord.errors)
        assert coord.updates.empty()
    finally:
        coord.stop()


# ---------------------------------------------------------------------------
# Vertical federation
# ---------------------------------------------------------------------------
def test_vertical_split_is_seeded_disjoint_cover():
    ids = np.array([30, 10, 20, 40, 50])
    row_order, masks = vertical_split(ids, 14, 3, seed=7)
    # row alignment: applying row_order sorts the shared sample ids
    np.testing.assert_array_equal(ids[row_order],
                                  np.sort(ids))
    # masks: sorted disjoint tuples covering every column exactly once
    flat = [c for m in masks for c in m]
    assert sorted(flat) == list(range(14))
    assert all(m == tuple(sorted(m)) for m in masks)
    assert all(isinstance(c, int) for m in masks for c in m)
    # deterministic in the seed
    _, again = vertical_split(ids, 14, 3, seed=7)
    assert again == masks
    _, other = vertical_split(ids, 14, 3, seed=8)
    assert other != masks
    with pytest.raises(ValueError, match="unique sample ids"):
        vertical_split(np.array([1, 1, 2]), 4, 2)
    with pytest.raises(ValueError, match="cannot slice"):
        vertical_split(ids, 2, 3)


def test_vertical_3silo_socket_round():
    """The examples/vertical_fedkt.py scenario at tiny config: three
    feature-masked silos (nn + rf + gbdt), every party holding ALL
    samples and a disjoint column slice, one real-TCP round — all three
    fold into ONE shared example domain, with measured framed wire
    bytes reported per domain."""
    data = tabular_binary(n=300, seed=0)
    n_rows = len(data["X_train"])
    row_order, masks = vertical_split(np.arange(n_rows), 14, 3, seed=0)
    bindings = [
        PartyBinding(NNLearner(MLP(len(masks[0]), 2, hidden=8),
                               num_classes=2, steps=10,
                               feature_mask=masks[0])),
        PartyBinding(RFLearner(num_classes=2, num_trees=4, depth=3,
                               feature_mask=masks[1]), engine="vmap"),
        PartyBinding(GBDTLearner(num_classes=2, num_rounds=4, depth=3,
                                 feature_mask=masks[2]), engine="vmap"),
    ]
    cfg = FedKTConfig(num_parties=3, num_partitions=1, num_subsets=2,
                      num_classes=2, seed=0)
    final = NNLearner(MLP(14, 2, hidden=8), num_classes=2, steps=10)
    res = FedKTSession(bindings, data, cfg, final_learner=final,
                       party_indices=[row_order.copy() for _ in range(3)],
                       transport=SocketTransport(parallelism=3)).run()
    assert 0.0 <= res.accuracy <= 1.0
    (ident,) = res.by_domain                    # ONE shared domain
    row = res.by_domain[ident]
    assert row["vote"].domain.unit == "example"
    assert row["parties"] == [0, 1, 2]
    assert len(row["labels"]) == len(data["X_public"])
    assert res.meta["wire_bytes"]["by_domain"][ident] == \
        res.meta["wire_bytes"]["updates"]
    assert len(res.meta["socket"]["framed_bytes"]) == 3


def test_vertical_masks_actually_restrict_features():
    """A feature-masked learner's predictions depend ONLY on its
    columns: perturbing off-mask columns never changes its output."""
    data = tabular_binary(n=256, seed=0)
    mask = (0, 3, 5)
    lrn = RFLearner(num_classes=2, num_trees=4, depth=3,
                    feature_mask=mask)
    st = lrn.fit(jax.random.PRNGKey(0), data["X_train"][:128],
                 data["y_train"][:128])
    X = data["X_test"][:32].copy()
    base = np.asarray(lrn.predict(st, X))
    X_off = X.copy()
    off_cols = [c for c in range(14) if c not in mask]
    X_off[:, off_cols] = 999.0
    np.testing.assert_array_equal(base,
                                  np.asarray(lrn.predict(st, X_off)))
    X_on = X.copy()
    X_on[:, list(mask)] = 999.0
    assert not np.array_equal(base, np.asarray(lrn.predict(st, X_on)))


def test_vertical_example_compiles():
    """The annotated walkthrough stays importable (tier-1 guards the
    tiny-config scenario above; the example itself is the full-size
    narration)."""
    import pathlib
    src = (pathlib.Path(__file__).parent.parent / "examples"
           / "vertical_fedkt.py").read_text()
    compile(src, "examples/vertical_fedkt.py", "exec")


# ---------------------------------------------------------------------------
# Launcher validation (the --learners bugfix)
# ---------------------------------------------------------------------------
def _args(**kw):
    ns = argparse.Namespace(parties=3, learner="nn", learners=None)
    for k, v in kw.items():
        setattr(ns, k, v)
    return ns


def test_federate_unknown_learner_kind_names_party():
    """--learners with an unknown kind fails UP FRONT with the party
    index and the registered kinds — not as a stray exception mid-round
    on some host."""
    with pytest.raises(SystemExit) as exc:
        federate.party_kinds(_args(learners="nn,bogus,rf"))
    msg = str(exc.value)
    assert "bogus" in msg and "party 1" in msg
    assert "nn" in msg and "rf" in msg and "gbdt" in msg
    assert "lm" in msg                 # the registry's wire kinds
    with pytest.raises(SystemExit, match="2 kinds"):
        federate.party_kinds(_args(learners="nn,rf"))
    assert federate.party_kinds(_args(learners="nn, rf ,gbdt")) == \
        ["nn", "rf", "gbdt"]
    assert federate.party_kinds(_args()) == ["nn", "nn", "nn"]
