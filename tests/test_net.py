"""Socket federation: streaming-aggregation bit-identity with the
serial loop, straggler/quorum dropout semantics, and wire-level frame
robustness (tests/test_transport.py covers the codec itself)."""
import socket
import struct
import time

import jax
import numpy as np
import pytest

from repro.configs.base import FedKTConfig
from repro.core.learners import GBDTLearner, NNLearner, RFLearner
from repro.data.synthetic import tabular_binary
from repro.federation import (Coordinator, FedKTSession, PartyBinding,
                              QuorumError, SocketTransport,
                              party_starting_keys)
from repro.federation.net import NAK, send_update_frame
from repro.federation.party import Party
from repro.models.smallnets import MLP


@pytest.fixture(scope="module")
def data():
    return tabular_binary(n=512, seed=0)


@pytest.fixture(scope="module")
def learner():
    return NNLearner(MLP(14, 2, hidden=8), num_classes=2, steps=20)


L2_CFG = dict(num_parties=3, num_partitions=1, num_subsets=2,
              num_classes=2, privacy_level="L2", gamma=0.1,
              query_fraction=0.5, seed=7)


@pytest.fixture(scope="module")
def ref_result(data, learner):
    """The serial in-process reference round for the shared L2 config."""
    return FedKTSession(learner, data, FedKTConfig(**L2_CFG),
                        engine="loop").run()


def _tree_equal(a, b):
    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(leaves_a) == len(leaves_b)
    for la, lb in zip(leaves_a, leaves_b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _failing_indices(n_parties, n_rows):
    """Valid shards for all parties except the last, whose out-of-range
    index makes its local round raise inside the worker."""
    shard = n_rows // n_parties
    ix = [np.arange(i * shard, (i + 1) * shard)
          for i in range(n_parties - 1)]
    return ix + [np.array([10 ** 9])]


class SlowParty(Party):
    """A party whose local round outlives the deadline."""
    delay_s = 6.0

    def local_round(self, key, X_public, num_queries, engine):
        time.sleep(self.delay_s)
        return super().local_round(key, X_public, num_queries, engine)


# ---------------------------------------------------------------------------
# Bit-identity with the serial loop
# ---------------------------------------------------------------------------
def test_socket_smoke_two_parties(data, learner):
    """Tier-1 CI smoke: a 2-party localhost socket round is bit-identical
    to the serial in-process loop — accuracy, epsilon, student states,
    and measured wire bytes."""
    cfg = FedKTConfig(**{**L2_CFG, "num_parties": 2})
    ref = FedKTSession(learner, data, cfg, engine="loop").run()
    res = FedKTSession(learner, data, cfg, engine="loop",
                       transport="socket").run()
    assert res.accuracy == ref.accuracy
    assert res.epsilon == ref.epsilon
    _tree_equal(res.student_states, ref.student_states)
    assert res.meta["wire_bytes"] == ref.meta["wire_bytes"]
    assert res.meta["transport"] == "socket"
    assert res.meta["dropped_parties"] == []
    assert sorted(res.meta["socket"]["arrived"]) == [0, 1]
    # the framed bytes in the socket report are the measured per-party
    # sizes the wire accounting sums
    assert sum(res.meta["socket"]["framed_bytes"].values()) == \
        res.meta["wire_bytes"]["updates"]


@pytest.mark.parametrize("make_learner", [
    lambda: NNLearner(MLP(14, 2, hidden=8), num_classes=2, steps=20),
    lambda: RFLearner(num_classes=2, num_trees=3, depth=2),
    lambda: GBDTLearner(num_rounds=3, depth=2),
], ids=["nn", "rf", "gbdt"])
def test_socket_matches_serial_loop(data, make_learner):
    """Acceptance: the socket session reproduces the serial loop
    bit-for-bit for every tabular learner kind when all parties
    respond — whatever order their updates arrive in."""
    cfg = FedKTConfig(**L2_CFG)
    lrn = make_learner()
    ref = FedKTSession(lrn, data, cfg, engine="loop").run()
    res = FedKTSession(lrn, data, cfg, engine="loop",
                       transport="socket", parallelism=3).run()
    assert res.accuracy == ref.accuracy
    assert res.epsilon == ref.epsilon
    _tree_equal(res.student_states, ref.student_states)
    assert res.meta["wire_bytes"] == ref.meta["wire_bytes"]


def test_socket_constant_memory_mode(data, learner, ref_result):
    """retain_students=False folds-and-drops every update: the result
    still matches the serial loop (the vote histogram IS the state),
    but no student states are retained."""
    res = FedKTSession(learner, data, FedKTConfig(**L2_CFG),
                       engine="loop", transport="socket",
                       retain_students=False).run()
    assert res.accuracy == ref_result.accuracy
    assert res.epsilon == ref_result.epsilon
    assert res.student_states == []
    assert res.meta["wire_bytes"] == ref_result.meta["wire_bytes"]


# ---------------------------------------------------------------------------
# Heterogeneous ensembles: rf + gbdt + nn in one round
# ---------------------------------------------------------------------------
def _het_bindings(native: bool):
    """One binding per L2_CFG party: forest, boosted trees, and MLP.
    ``native=True`` gives each party its own preferred engine — stacked
    vmap fits for the tree parties, the serial loop for the nn party —
    so engines genuinely differ WITHIN the round; False runs everything
    on the session's loop default."""
    tree_eng = "vmap" if native else None
    return [
        PartyBinding(RFLearner(num_classes=2, num_trees=3, depth=2),
                     engine=tree_eng),
        PartyBinding(GBDTLearner(num_rounds=3, depth=2),
                     engine=tree_eng),
        PartyBinding(NNLearner(MLP(14, 2, hidden=8), num_classes=2,
                               steps=20)),
    ]


@pytest.fixture(scope="module")
def het_ref(data, learner):
    """Serial in-process reference for the mixed rf + gbdt + nn round
    (all-loop bindings)."""
    return FedKTSession(_het_bindings(native=False), data,
                        FedKTConfig(**L2_CFG),
                        final_learner=learner).run()


@pytest.mark.parametrize("native", [False, True],
                         ids=["loop", "native-engines"])
@pytest.mark.parametrize("transport", ["inprocess", "thread", "socket"])
def test_heterogeneous_round_agrees_across_transports(
        data, learner, het_ref, transport, native):
    """Acceptance: a 3-party rf + gbdt + nn session runs end-to-end and
    is bit-identical across transports — under all-loop bindings AND
    with each party on its native engine (stacked tree fits and nn
    vmap are bit-identical to their serial fits, so the per-party
    engine choice cannot leak into the round result)."""
    res = FedKTSession(_het_bindings(native), data,
                       FedKTConfig(**L2_CFG), final_learner=learner,
                       transport=transport).run()
    assert res.accuracy == het_ref.accuracy
    assert res.epsilon == het_ref.epsilon
    _tree_equal(res.student_states, het_ref.student_states)
    _tree_equal(res.final_state, het_ref.final_state)
    assert res.meta["wire_bytes"] == het_ref.meta["wire_bytes"]
    # each silo's model family is priced separately on the wire
    by_kind = res.meta["wire_bytes"]["by_learner_kind"]
    assert sorted(by_kind) == ["gbdt", "nn", "rf"]
    assert sum(by_kind.values()) == res.meta["wire_bytes"]["updates"]
    assert [b["learner"] for b in res.meta["party_bindings"]] \
        == ["rf", "gbdt", "nn"]
    assert res.meta["engine"] == ("mixed" if native else "loop")


def test_heterogeneous_fold_is_arrival_order_independent(data, learner,
                                                         het_ref):
    """The mixed-learner histogram is an integer sum: folding the same
    three updates in reversed arrival order produces identical vote
    counts, labels, epsilon, and final model."""
    session = FedKTSession(_het_bindings(native=False), data,
                           FedKTConfig(**L2_CFG), final_learner=learner)
    Xpub = session.data["X_public"]
    party_keys, key = party_starting_keys(session.parties,
                                          session.cfg.seed)
    updates = session.transport.run_round(
        session.parties, party_keys, Xpub, session.tq_party, None)
    results = []
    for order in (updates, list(reversed(updates))):
        agg = session.server.make_aggregate(Xpub, session.tq_server,
                                            session.engine)
        for upd in order:
            agg.add(upd)
        final_state, vote, _ = session.server.finalize(key, agg)
        results.append((agg, vote, final_state))
    (agg_f, vote_f, fin_f), (agg_r, vote_r, fin_r) = results
    np.testing.assert_array_equal(np.asarray(agg_f.counts),
                                  np.asarray(agg_r.counts))
    np.testing.assert_array_equal(np.asarray(vote_f.labels),
                                  np.asarray(vote_r.labels))
    assert agg_f.epsilon(vote_f) == agg_r.epsilon(vote_r)
    _tree_equal(fin_f, fin_r)
    assert fin_f is not None and het_ref.epsilon == agg_f.epsilon(vote_f)


# ---------------------------------------------------------------------------
# Straggler / quorum semantics
# ---------------------------------------------------------------------------
def test_failed_party_dropped_at_quorum(data, learner, ref_result):
    """A party that dies mid-round is excluded: the session completes
    with the quorum's updates and records the dropout in meta."""
    cfg = FedKTConfig(**L2_CFG)
    res = FedKTSession(
        learner, data, cfg, engine="loop",
        party_indices=_failing_indices(3, len(data["X_train"])),
        transport=SocketTransport(min_parties=2)).run()
    assert res.meta["dropped_parties"] == [2]
    assert 2 in res.meta["socket"]["failed"]
    assert sorted(res.meta["socket"]["arrived"]) == [0, 1]
    assert len(res.student_states) == 2
    # accounting covers only the arrived updates
    two_thirds = 2 * ref_result.meta["wire_bytes"]["labels"] // 3
    assert res.meta["wire_bytes"]["labels"] == two_thirds
    assert res.epsilon is not None and res.epsilon > 0


def test_slow_party_dropped_at_deadline(data, learner):
    """A straggler that outlives deadline_s is dropped once min_parties
    updates arrived; the round does NOT wait for it."""
    cfg = FedKTConfig(**L2_CFG)
    session = FedKTSession(
        learner, data, cfg, engine="loop",
        transport=SocketTransport(min_parties=2, deadline_s=3.0))
    slow = session.parties[2]
    session.parties[2] = SlowParty(
        party_id=slow.party_id, X=slow.X, y=slow.y,
        indices=slow.indices, cfg=slow.cfg, learner=slow.learner,
        student_learner=slow.student_learner)
    t0 = time.monotonic()
    res = session.run()
    assert time.monotonic() - t0 < SlowParty.delay_s + 15
    assert res.meta["dropped_parties"] == [2]
    assert sorted(res.meta["socket"]["arrived"]) == [0, 1]


def test_below_quorum_raises(data, learner):
    """Default quorum is ALL parties: a failed party with no quorum
    slack is a loud error naming the missing silo, not a silent
    degradation."""
    cfg = FedKTConfig(**L2_CFG)
    with pytest.raises(QuorumError, match=r"missing parties \[2\]"):
        FedKTSession(
            learner, data, cfg, engine="loop",
            party_indices=_failing_indices(3, len(data["X_train"])),
            transport="socket").run()


# ---------------------------------------------------------------------------
# Wire-level robustness
# ---------------------------------------------------------------------------
def _raw_frame(port, payload):
    with socket.create_connection(("127.0.0.1", port), timeout=10) as s:
        s.sendall(struct.pack("<I", len(payload)) + payload)
        return s.recv(1)


def test_coordinator_rejects_incompatible_frames(data, learner):
    """Garbage and old-codec-version frames get a NAK and are recorded,
    never folded; a well-formed frame from an unknown party is refused
    too."""
    coord = Coordinator([0], port=0).start()
    try:
        assert _raw_frame(coord.port, b"garbage") == NAK
        # a pre-version frame: old magic b"FKT1" + plausible tail
        assert _raw_frame(coord.port,
                          b"FKT1" + struct.pack("<I", 2) + b"{}") == NAK
        assert len(coord.errors) == 2
        assert any("version" in e for e in coord.errors)
        # unknown party: encode a real update under an id not in round
        party = Party(party_id=9, X=data["X_train"], y=data["y_train"],
                      indices=np.arange(64),
                      cfg=FedKTConfig(**{**L2_CFG, "num_parties": 1}),
                      learner=learner, student_learner=learner)
        from repro.federation.codec import encode_update
        from repro.federation.engines import LoopEngine
        upd, _ = party.local_round(jax.random.PRNGKey(0),
                                   data["X_public"], 16, LoopEngine())
        with pytest.raises(ConnectionError, match="NAK"):
            send_update_frame("127.0.0.1", coord.port,
                              encode_update(upd), retries=1)
        assert coord.updates.empty()
    finally:
        coord.stop()


def test_client_retries_with_backoff():
    """The party client survives a coordinator that binds late (the
    cross-host race), and gives a clear error when it never appears."""
    with pytest.raises(ConnectionError, match="after 2 attempts"):
        send_update_frame("127.0.0.1", 1, b"x", retries=2,
                          backoff_s=0.01)


def test_transport_context_manager():
    """Transports are context managers with idempotent close."""
    with SocketTransport(min_parties=1) as t:
        assert t.name == "socket"
    t.close()


# ---------------------------------------------------------------------------
# Fleet scale (scheduled full run)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_fleet_32_parties_streaming(learner):
    """32 parties stream through one localhost coordinator under the
    constant-memory fold; result is bit-identical to the serial loop."""
    fleet_data = tabular_binary(n=4096, seed=1)
    cfg = FedKTConfig(num_parties=32, num_partitions=1, num_subsets=2,
                      num_classes=2, privacy_level="L2", gamma=0.1,
                      query_fraction=0.5, seed=11)
    # equal shards: one pow2 training bucket for the whole fleet
    rows = (len(fleet_data["X_train"]) // 32) * 32
    ix = np.array_split(np.arange(rows), 32)
    ref = FedKTSession(learner, fleet_data, cfg, engine="loop",
                       party_indices=ix).run()
    res = FedKTSession(learner, fleet_data, cfg, engine="loop",
                       party_indices=ix, retain_students=False,
                       transport=SocketTransport(parallelism=8)).run()
    assert res.accuracy == ref.accuracy
    assert res.epsilon == ref.epsilon
    assert res.student_states == []
    assert res.meta["wire_bytes"] == ref.meta["wire_bytes"]
    assert res.meta["num_updates"] == 32
    assert res.meta["dropped_parties"] == []
