"""End-to-end system behaviour: the paper's qualitative claims at test
scale (synthetic data stand-ins, DESIGN.md §2)."""
import pytest

from repro.configs.base import FedKTConfig
from repro.core.baselines import IterConfig
from repro.core.learners import NNLearner
from repro.core.partition import dirichlet_partition
from repro.federation import (CentralPATEStrategy, FedKTSession,
                              IterativeStrategy, SoloStrategy)
from repro.data.synthetic import tabular_binary
from repro.models.smallnets import MLP

# full-size federation runs: minutes of CPU — scheduled full suite only
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def data():
    return tabular_binary(n=8000, seed=0)


@pytest.fixture(scope="module")
def learner():
    return NNLearner(MLP(14, 2, hidden=32), num_classes=2, steps=200)


@pytest.fixture(scope="module")
def fedkt_result(data, learner):
    # cross-silo setting with real heterogeneity: with few parties and
    # mild skew SOLO is nearly as good as federation (each silo holds
    # plenty of data) and the paper's gap only appears under label skew
    cfg = FedKTConfig(num_parties=8, num_partitions=2, num_subsets=2,
                      num_classes=2, beta=0.3, seed=0)
    return cfg, FedKTSession(learner, data, cfg, engine="loop").run()


def test_fedkt_beats_solo(data, learner, fedkt_result):
    cfg, res = fedkt_result
    solo = SoloStrategy(learner).run(data, cfg).accuracy
    assert res.accuracy > solo + 0.02, (res.accuracy, solo)


def test_fedkt_close_to_central_pate(data, learner, fedkt_result):
    cfg, res = fedkt_result
    pate = CentralPATEStrategy(learner).run(data, cfg).accuracy
    assert res.accuracy > pate - 0.08, (res.accuracy, pate)


@pytest.mark.xfail(
    reason="Does not reproduce at test scale: on this synthetic tabular "
    "stand-in an MLP is exactly the model class FedAvg is built for, and "
    "two full FedAvg rounds see ALL local data while each FedKT teacher "
    "sees only 1/(s*t) of its party's shard before distillation.  Swept "
    "beta in {0.3, 0.15} x seed in {0, 1, 2}: FedAvg-r2 wins 5/6 configs "
    "(margins -0.026 to -0.195; single win +0.073 at beta=0.15, seed=2), "
    "so this is a systematic small-scale gap, not a threshold/seed flake. "
    "The paper's Table 1 claim is about its real datasets at full scale; "
    "revisit if a paper-scale data pipeline lands.", strict=False)
def test_fedkt_beats_two_round_fedavg(data, learner, fedkt_result):
    """Equal-communication comparison (paper Table 1: r=2 when s=2)."""
    cfg, res = fedkt_result
    parts = dirichlet_partition(data["y_train"], cfg.num_parties, cfg.beta,
                                cfg.seed)
    out = IterativeStrategy(
        MLP(14, 2, hidden=32),
        IterConfig(algo="fedavg", rounds=2, local_steps=50)).run(
            data, party_indices=parts)
    assert res.accuracy > out.meta["acc_per_round"][-1] - 0.02


def test_fedkt_dp_eps_reported(data, learner):
    # eps accounting: reported, positive, monotone in gamma.  (Accuracy
    # under heavy noise with only 4 parties is near-chance — the paper's
    # DP accuracy claims need >=20 parties; see benchmarks/table2.)
    eps = {}
    for gamma in (0.05, 0.3):
        cfg = FedKTConfig(num_parties=4, num_partitions=1, num_subsets=3,
                          num_classes=2, privacy_level="L1", gamma=gamma,
                          query_fraction=0.1, seed=0)
        res = FedKTSession(learner, data, cfg, engine="loop").run()
        assert res.epsilon is not None and 0 < res.epsilon < 1000
        assert res.accuracy > 0.3
        eps[gamma] = res.epsilon
    assert eps[0.05] < eps[0.3]


def test_train_step_runs_via_driver():
    """LM driver smoke: a few steps reduce loss on synthetic tokens."""
    from repro.configs import TrainConfig, get_smoke
    from repro.data import TokenDataset, synthetic
    from repro.launch.train import train_lm
    from repro.models import Model

    cfg = get_smoke("stablelm-3b")
    model = Model(cfg)
    data = synthetic.tokens(n_seqs=64, seq_len=65, vocab=cfg.vocab_size)
    tcfg = TrainConfig(batch_size=8, seq_len=64, steps=30,
                       learning_rate=3e-3)
    out = train_lm(model, TokenDataset(data["train"]), tcfg, verbose=False)
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    assert last < first - 0.2, (first, last)
