"""Partitioning invariants (hypothesis property tests)."""
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.partition import (dirichlet_partition, homogeneous_partition,
                                  subsets_of_partition)


@given(st.integers(2, 10), st.floats(0.1, 10.0), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_dirichlet_partition_covers_disjointly(n_parties, beta, seed):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 5, 500)
    parts = dirichlet_partition(y, n_parties, beta, seed, min_size=1)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(y)
    assert len(np.unique(allidx)) == len(y)      # disjoint cover


def test_dirichlet_skew_increases_as_beta_shrinks():
    y = np.random.default_rng(0).integers(0, 10, 5000)

    def skew(beta):
        parts = dirichlet_partition(y, 10, beta, seed=1, min_size=1)
        # mean over parties of the max class fraction
        fracs = []
        for ix in parts:
            c = np.bincount(y[ix], minlength=10)
            fracs.append(c.max() / max(c.sum(), 1))
        return np.mean(fracs)

    assert skew(0.1) > skew(10.0)


@given(st.integers(1, 4), st.integers(2, 8), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_subsets_disjoint_union(s, t, seed):
    rng = np.random.default_rng(seed)
    local = rng.choice(1000, size=100, replace=False)
    plan = subsets_of_partition(local, s, t, seed)
    assert len(plan) == s
    for part in plan:
        assert len(part) == t
        allidx = np.concatenate(part)
        assert sorted(allidx) == sorted(local)   # each partition covers all
        assert len(np.unique(allidx)) == len(local)


def test_homogeneous_partition():
    parts = homogeneous_partition(103, 10, seed=0)
    sizes = [len(p) for p in parts]
    assert sum(sizes) == 103 and max(sizes) - min(sizes) <= 1
