"""Optional-hypothesis shim: property tests skip (instead of erroring
at collection) when hypothesis isn't installed, while the plain tests
in the same modules keep running.

    from hypothesis_compat import given, settings, st

is a drop-in for ``from hypothesis import given, settings,
strategies as st`` — when hypothesis is present it IS hypothesis.
"""
try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """st.<anything>(...) placeholder; never drawn from because the
        decorated test body is replaced by a skip."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def decorate(f):
            def skipper():      # no params: pytest must not see f's args
                pytest.skip("hypothesis not installed")
            skipper.__name__ = f.__name__
            skipper.__doc__ = f.__doc__
            return skipper
        return decorate
