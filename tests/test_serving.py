"""Serving-tier lockdown: scheduler parity + engine invariants.

The headline suite of the serving PR.  Three layers:

  1. Pure-scheduler properties (no jax): pow2 bucket rounding, FIFO
     bucket-match admission, slot lifecycle — random request streams
     driven through a model-free replay of the engine loop, checked
     against the cache-safety invariants (positions strictly below
     cache_len, no slot aliasing, eviction frees exactly the evicted
     slot).  Hypothesis variants run where available; seeded plain
     variants always run, so the logic is exercised in every tier.
  2. Engine parity: continuous-batched output is bit-identical PER
     REQUEST to the serial ``serve_batch`` reference — staggered
     arrivals, mixed prompt lengths sharing one bucket, arrival-order
     permutations, EOS early exit — on the tiny 1-layer LM and on the
     real smoke archs (global attention and the sliding-window ring).
  3. Compile discipline: after ``Engine.warmup`` the jit trace-cache
     sizes never move again, no matter how traffic staggers.
"""
import importlib.util
import os

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from conftest import smoke_model, tiny_lm_config  # noqa: F401

SEED_STREAMS = [0, 1, 2]


# ---------------------------------------------------------------------------
# 1. scheduler: units + properties (no jax, runs in milliseconds)
# ---------------------------------------------------------------------------
def _sched(**kw):
    from repro.serving import Scheduler
    base = dict(num_slots=4, cache_len=64, min_bucket=8)
    base.update(kw)
    return Scheduler(**base)


def test_round_pow2_basics():
    from repro.serving import round_pow2
    assert [round_pow2(n) for n in (1, 2, 3, 5, 8, 9)] == \
        [1, 2, 4, 8, 8, 16]
    assert round_pow2(3, lo=8) == 8
    with pytest.raises(ValueError):
        round_pow2(0)


def test_bucket_of_caps_at_cache_len():
    s = _sched(cache_len=48)           # non-pow2 cache: cap must bind
    assert s.bucket_of(5) == 8
    assert s.bucket_of(33) == 48       # pow2 would be 64 > cache rows
    assert _sched(cache_len=64).bucket_of(33) == 64


def test_submit_validates_and_clamps():
    s = _sched(cache_len=64)
    with pytest.raises(ValueError):
        s.submit(np.arange(64), 4)     # plen == cache_len: no decode room
    with pytest.raises(ValueError):
        s.submit(np.zeros((0,)), 4)
    r = s.submit(np.arange(60), max_tokens=100)
    assert r.max_tokens == 4           # clamped to cache_len - plen


def test_fifo_bucket_match_admission():
    s = _sched(num_slots=3)
    a = s.submit(np.arange(5), 4)      # bucket 8
    b = s.submit(np.arange(20), 4)     # bucket 32 — different, waits
    c = s.submit(np.arange(7), 4)      # bucket 8 — joins a
    adm = s.next_admission()
    assert [r.rid for r in adm.reqs] == [a.rid, c.rid]
    assert adm.bucket_len == 8 and adm.batch == 2
    assert b.status == "waiting"
    # head b now fixes bucket 32; only 1 slot left
    adm2 = s.next_admission()
    assert [r.rid for r in adm2.reqs] == [b.rid] and adm2.batch == 1
    assert s.next_admission() is None  # no free slots


def test_slot_allocator_lifecycle():
    from repro.serving import SlotAllocator
    al = SlotAllocator(2)
    assert al.acquire() == 0 and al.acquire() == 1
    with pytest.raises(RuntimeError):
        al.acquire()
    al.release(0)
    with pytest.raises(ValueError):
        al.release(0)                  # double free
    with pytest.raises(ValueError):
        al.release(5)                  # out of range
    assert al.acquire() == 0           # lowest-free-first


def test_evict_requires_running():
    s = _sched()
    r = s.submit(np.arange(4), 2)
    with pytest.raises(ValueError):
        s.evict(r, "eos")


# -- model-free replay of the engine loop, instrumented -------------------
def drive_scheduler(sched, stream, rng):
    """Replays the engine's admit+sweep loop without a model: ``stream``
    is [(plen, max_tokens)] submitted a random 0-2 per step.  Asserts
    the cache-safety invariants every step; returns finished requests.
    """
    pending = list(stream)
    done, occupied = [], {}

    def emit(r):                          # mirrors Engine._emit
        r.tokens.append(0)
        if len(r.tokens) >= r.max_tokens:
            slot, before = r.slot, set(occupied)
            sched.evict(r, "length")
            del occupied[slot]
            # eviction freed exactly the evicted slot
            assert set(sched.slots.free) & before == {slot}
            done.append(r)

    while pending or not sched.idle:
        for _ in range(int(rng.integers(0, 3))):
            if pending:
                plen, mt = pending.pop(0)
                sched.submit(np.zeros(plen, np.int32), mt)
        adm = sched.next_admission()
        if adm is not None:
            assert adm.batch >= len(adm.reqs) > 0
            assert adm.batch & (adm.batch - 1) == 0       # pow2
            for r in adm.reqs:
                assert adm.bucket_len >= r.plen           # fits bucket
                assert adm.bucket_len <= sched.cache_len  # fits rows
                assert r.slot not in occupied             # no aliasing
                occupied[r.slot] = r
                emit(r)                                   # prefill token
        for r in list(sched.running):                     # decode sweep
            assert r.next_pos < sched.cache_len           # never overflow
            emit(r)
    assert not occupied and len(sched.slots.free) == sched.num_slots
    return done


def _rand_stream(rng, n, cache_len):
    return [(int(rng.integers(1, cache_len)),
             int(rng.integers(1, 2 * cache_len)))
            for _ in range(n)]


@pytest.mark.parametrize("seed", SEED_STREAMS)
def test_scheduler_stream_invariants(seed):
    rng = np.random.default_rng(seed)
    cache_len = int(rng.choice([32, 48, 64]))
    sched = _sched(num_slots=int(rng.integers(1, 6)),
                   cache_len=cache_len)
    done = drive_scheduler(sched, _rand_stream(rng, 25, cache_len), rng)
    assert len(done) == 25
    for r in done:
        # budget respected AND clamped: no position ever hit cache_len
        assert len(r.tokens) == r.max_tokens
        assert r.plen + len(r.tokens) <= cache_len


@given(seed=st.integers(0, 10_000), slots=st.integers(1, 6),
       cache=st.sampled_from([32, 48, 64]), n=st.integers(1, 30))
@settings(max_examples=25, deadline=None)
def test_scheduler_stream_invariants_prop(seed, slots, cache, n):
    rng = np.random.default_rng(seed)
    sched = _sched(num_slots=slots, cache_len=cache)
    assert len(drive_scheduler(sched, _rand_stream(rng, n, cache),
                               rng)) == n


@given(plen=st.integers(1, 63))
@settings(max_examples=50, deadline=None)
def test_bucket_rounding_prop(plen):
    s = _sched(cache_len=64)
    b = s.bucket_of(plen)
    assert b >= plen and b >= s.min_bucket and b <= s.cache_len
    assert b & (b - 1) == 0
    if b > s.min_bucket:               # minimality: half would not fit
        assert b // 2 < plen


# ---------------------------------------------------------------------------
# 2. serve_batch: EOS-masked stats (the satellite fix)
# ---------------------------------------------------------------------------
def test_effective_tokens():
    from repro.serving import effective_tokens
    toks = np.array([[3, 9, 9, 9],     # EOS at step 0 -> 1 token
                     [5, 6, 3, 8],     # EOS mid-stream -> 3
                     [5, 6, 7, 8]])    # no EOS -> all 4
    assert effective_tokens(toks, 3).tolist() == [1, 3, 4]
    assert effective_tokens(toks, None).tolist() == [4, 4, 4]


def test_serve_batch_stats(tiny_lm):
    from repro.serving import serve_batch
    cfg, model = tiny_lm
    import jax
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8)).astype(np.int32)
    toks, stats = serve_batch(model, params, prompts, 6, verbose=False)
    assert toks.shape == (3, 6)
    # pick an emitted token as EOS: masked accounting must drop the tail
    eos = int(toks[0, 2])
    _, s2 = serve_batch(model, params, prompts, 6, eos_id=eos,
                        verbose=False)
    assert s2["generated"] == sum(s2["effective_lens"]) < 18
    assert s2["tok_per_s"] == pytest.approx(
        s2["generated"] / s2["decode_s"], rel=1e-6)


def test_launch_serve_reexport():
    """Back-compat: launch.serve still exposes serve_batch (now the
    serving package's)."""
    from repro.launch import serve as launch_serve
    from repro.serving import serve_batch
    assert launch_serve.serve_batch is serve_batch


# ---------------------------------------------------------------------------
# 3. engine parity vs the serial reference
# ---------------------------------------------------------------------------
def _serial_refs(model, params, prompts, gen):
    from repro.serving import serve_batch
    refs = []
    for p in prompts:
        toks, _ = serve_batch(model, params, p[None], gen, verbose=False)
        refs.append(toks[0].tolist())
    return refs


def _mixed_prompts(cfg, plens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (p,)).astype(np.int32)
            for p in plens]


@pytest.fixture(scope="module")
def tiny_serving(tiny_lm):
    import jax
    cfg, model = tiny_lm
    params = model.init(jax.random.PRNGKey(0))
    prompts = _mixed_prompts(cfg, [3, 5, 8, 12, 16, 13])
    refs = _serial_refs(model, params, prompts, 10)
    return cfg, model, params, prompts, refs


def _engine(model, params, **kw):
    from repro.serving import Engine
    base = dict(num_slots=4, cache_len=64)
    base.update(kw)
    return Engine(model, params, **base)


def test_parity_staggered_arrivals(tiny_serving):
    """Mixed prompt lengths, arrivals staggered across steps, more
    requests than slots: every stream bit-identical to its solo serial
    run."""
    cfg, model, params, prompts, refs = tiny_serving
    eng = _engine(model, params)
    eng.submit(prompts[0], 10)
    eng.submit(prompts[1], 10)
    eng.step()
    eng.submit(prompts[2], 10)
    eng.submit(prompts[3], 10)
    eng.step()
    eng.step()
    eng.submit(prompts[4], 10)
    eng.submit(prompts[5], 10)
    res = eng.run()
    assert len(res) == len(prompts)
    for r in res:
        assert r.tokens == refs[r.rid], f"rid {r.rid} diverged"
        assert r.finish_reason == "length"
        assert r.num_tokens == 10
        assert len(r.timing["token_latencies"]) == 10
        assert r.timing["total"] >= r.timing["ttft"] >= \
            r.timing["queue"] >= 0


def test_parity_arrival_order_invariance(tiny_serving):
    """The same request set in permuted submit orders yields the same
    per-prompt streams (scheduling changes WHEN, never WHAT)."""
    cfg, model, params, prompts, refs = tiny_serving
    for perm in ([2, 0, 4, 1, 5, 3], [5, 4, 3, 2, 1, 0]):
        eng = _engine(model, params, num_slots=2)
        rid_to_prompt = {}
        for i in perm:
            r = eng.submit(prompts[i], 10)
            rid_to_prompt[r.rid] = i
        for r in eng.run():
            assert r.tokens == refs[rid_to_prompt[r.rid]], \
                f"order {perm}: prompt {rid_to_prompt[r.rid]} diverged"


def test_parity_one_bucket_mixed_lengths(tiny_serving):
    """Lengths 3/5/8 round to ONE 8-bucket and prefill in one dispatch;
    right-padding must be invisible (causal masking + true-plen
    readout)."""
    cfg, model, params, prompts, refs = tiny_serving
    eng = _engine(model, params)
    for i in (0, 1, 2):
        eng.submit(prompts[i], 10)
    adm_counts = eng.compile_counts()
    res = eng.run()
    # all three went through a single prefill shape: one trace
    assert eng.compile_counts()["prefill"] - adm_counts["prefill"] <= 1
    for r in res:
        assert r.tokens == refs[r.rid]


def test_parity_eos_early_exit(tiny_serving):
    """EOS eviction: the engine's stream is the PREFIX of the serial
    stream up to and including the first EOS, reason recorded, and the
    freed slot is reused by a later request."""
    cfg, model, params, prompts, refs = tiny_serving
    eos = refs[2][3]                   # token the ref emits at step 3
    eng = _engine(model, params, num_slots=2, eos_id=eos)
    for p in prompts[:4]:
        eng.submit(p, 10)
    res = eng.run()
    assert len(res) == 4
    for r in res:
        ref = refs[r.rid]
        cut = ref.index(eos) + 1 if eos in ref else len(ref)
        assert r.tokens == ref[:cut]
        want = "eos" if eos in ref else "length"
        assert r.finish_reason == want
    assert any(r.finish_reason == "eos" for r in res)


def test_engine_serve_closed_loop(tiny_serving):
    cfg, model, params, prompts, refs = tiny_serving
    eng = _engine(model, params, num_slots=8)
    res = eng.serve(prompts, max_tokens=10)
    assert [r.tokens for r in res] == refs


# -- compile discipline ---------------------------------------------------
def test_zero_recompiles_after_warmup(tiny_serving):
    """Warm the bucket set, then throw staggered mixed traffic at the
    engine: trace-cache sizes must not move."""
    cfg, model, params, prompts, refs = tiny_serving
    eng = _engine(model, params)
    warm = eng.warmup(buckets=[p.shape[0] for p in prompts])
    assert warm["decode"] == 1
    rid_to_prompt = {}
    for i in (0, 3):
        rid_to_prompt[eng.submit(prompts[i], 10).rid] = i
    eng.step()
    for i in (2, 4, 5):
        rid_to_prompt[eng.submit(prompts[i], 10).rid] = i
    res = eng.run()
    assert eng.compile_counts() == warm, "recompile after warmup"
    for r in res:
        assert r.tokens == refs[rid_to_prompt[r.rid]]


# -- real smoke archs: global attention and the sliding-window ring -------
def test_parity_smoke_global_attention():
    import jax
    cfg, model = smoke_model("phi4-mini-3.8b", dtype="float32",
                             param_dtype="float32")
    params = model.init(jax.random.PRNGKey(0))
    prompts = _mixed_prompts(cfg, [5, 8, 12], seed=1)
    refs = _serial_refs(model, params, prompts, 8)
    eng = _engine(model, params, num_slots=2)
    eng.submit(prompts[0], 8)
    eng.submit(prompts[1], 8)
    eng.step()
    eng.submit(prompts[2], 8)
    for r in eng.run():
        assert r.tokens == refs[r.rid]


def test_parity_smoke_ring_window_crossing():
    """gemma2 smoke (window 64): prompts shorter AND longer than the
    window, so insert_cache's per-request ring conversion and the
    sliding mask both get exercised mid-stream."""
    import jax
    cfg, model = smoke_model("gemma2-27b", dtype="float32",
                             param_dtype="float32")
    assert cfg.window == 64
    params = model.init(jax.random.PRNGKey(0))
    prompts = _mixed_prompts(cfg, [30, 70, 100], seed=2)
    refs = _serial_refs(model, params, prompts, 8)
    eng = _engine(model, params, num_slots=2, cache_len=256)
    eng.submit(prompts[0], 8)
    eng.submit(prompts[1], 8)
    eng.step()
    eng.submit(prompts[2], 8)
    for r in eng.run():
        assert r.tokens == refs[r.rid], \
            f"ring parity broke at plen {r.prompt_len}"


# -- config gating --------------------------------------------------------
def test_engine_refuses_recurrent_and_encdec():
    from repro.serving import Engine
    import jax
    cfg, model = smoke_model("recurrentgemma-2b")
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="recurrent"):
        Engine(model, params)
    cfg2, model2 = smoke_model("whisper-tiny")
    params2 = model2.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="decoder-only"):
        Engine(model2, params2)


def test_engine_refuses_short_cache_for_window():
    from repro.serving import Engine
    import jax
    cfg, model = smoke_model("gemma2-27b", dtype="float32",
                             param_dtype="float32")
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="window"):
        Engine(model, params, cache_len=32)   # < window 64


# ---------------------------------------------------------------------------
# 4. demo + bench smokes (tier-1 guards, tree_fit_bench pattern)
# ---------------------------------------------------------------------------
def _load_example(name):
    path = os.path.join(os.path.dirname(__file__), "..", "examples", name)
    spec = importlib.util.spec_from_file_location(name[:-3], path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_serve_demo_smoke(tmp_path):
    """examples/serve_demo.py end to end on the tiny flow: federated
    round -> checkpoint -> engine, parity asserted inside the demo."""
    demo = _load_example("serve_demo.py")
    out = demo.main(tiny=True, ckpt_dir=str(tmp_path), verbose=False)
    assert out["parity"] is True
    assert out["results"] and all(r.num_tokens > 0
                                  for r in out["results"])


def test_serve_bench_smoke(tmp_path):
    """benchmarks/serve_bench.py tiny mode: same code path as the
    committed BENCH_serving.json, toy shapes, no write."""
    from benchmarks.serve_bench import bench
    rec = bench(tiny=True, write=False)
    for n in rec["streams"]:
        row = rec["streams"][n]
        assert row["tok_per_s"] > 0
        assert row["p95_token_latency_ms"] >= \
            row["p50_token_latency_ms"] > 0
    assert rec["serial_reference"]["tok_per_s"] > 0


# ---------------------------------------------------------------------------
# 5. slow soak: 16 concurrent streams through 4 slots
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_soak_16_streams(tiny_lm):
    import jax
    cfg, model = tiny_lm
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(7)
    plens = rng.integers(2, 40, 16).tolist()
    prompts = [rng.integers(0, cfg.vocab_size, (p,)).astype(np.int32)
               for p in plens]
    refs = _serial_refs(model, params, prompts, 12)
    eng = _engine(model, params, num_slots=4, cache_len=64)
    eng.warmup(buckets=plens)
    warm = eng.compile_counts()
    # open-loop arrivals: drip the 16 streams in while decoding
    it = iter(enumerate(prompts))
    rid_to_prompt, res = {}, []
    pending = True
    while pending or not eng.scheduler.idle:
        for _ in range(2):
            try:
                i, p = next(it)
            except StopIteration:
                pending = False
                break
            rid_to_prompt[eng.submit(p, 12).rid] = i
        res.extend(eng.step())
    assert len(res) == 16
    assert eng.compile_counts() == warm
    for r in res:
        assert r.tokens == refs[rid_to_prompt[r.rid]]
