import jax
import numpy as np

from repro import checkpoint
from repro.configs import get_smoke
from repro.models import Model


def test_roundtrip(tmp_path):
    cfg = get_smoke("stablelm-3b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, params, step=7, metrics={"loss": 1.5})
    restored = checkpoint.restore(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    m = checkpoint.manifest(path)
    assert m["step"] == 7 and m["metrics"]["loss"] == 1.5
