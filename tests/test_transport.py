"""Transport layer: codec round-trips, versioned-frame rejection, wire
accounting, cross-transport bit-identity of the federation round, and
the worker-cleanup contract when a party fails mid-round."""
import multiprocessing

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.configs.base import FedKTConfig
from repro.core.learners import GBDTLearner, NNLearner, RFLearner
from repro.data.synthetic import tabular_binary
from repro.federation import (FedKTSession, InProcessTransport, PartyUpdate,
                              ThreadTransport, TokenLabels, codec,
                              get_transport, pytree_bytes)
from repro.models.smallnets import MLP


@pytest.fixture(scope="module")
def data():
    return tabular_binary(n=512, seed=0)


@pytest.fixture(scope="module")
def learner():
    return NNLearner(MLP(14, 2, hidden=8), num_classes=2, steps=20)


def _tree_equal(a, b):
    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(leaves_a) == len(leaves_b)
    for la, lb in zip(leaves_a, leaves_b):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.dtype == lb.dtype and la.shape == lb.shape
        np.testing.assert_array_equal(la, lb)


# ---------------------------------------------------------------------------
# Codec
# ---------------------------------------------------------------------------
def _roundtrip(tree):
    buf = codec.encode(tree)
    out, header = codec.decode(buf)
    assert codec.encoded_nbytes(tree) == len(buf)
    return out, buf


@pytest.mark.parametrize("make_learner", [
    lambda: NNLearner(MLP(14, 2, hidden=8), num_classes=2, steps=10),
    lambda: RFLearner(num_classes=2, num_trees=3, depth=2),
    lambda: GBDTLearner(num_rounds=3, depth=2),
], ids=["nn", "rf", "gbdt"])
def test_codec_roundtrips_student_states(data, make_learner):
    """encode∘decode identity over every student-state pytree kind the
    protocol ships (dict params, nested forest/edges tuples)."""
    lrn = make_learner()
    states = [lrn.fit(jax.random.fold_in(jax.random.PRNGKey(0), i),
                      data["X_train"][:64], data["y_train"][:64])
              for i in range(2)]
    out, buf = _roundtrip({"students": states})
    _tree_equal(states, out["students"])
    assert isinstance(out["students"], list)


def test_codec_mixed_dtypes_and_containers():
    tree = {
        "f32": np.arange(6, dtype=np.float32).reshape(2, 3),
        "f64": np.linspace(0, 1, 4),
        "f16": np.ones((3,), np.float16),
        "bf16": jnp.full((2, 2), 1.5, jnp.bfloat16),
        "ints": (np.int32(7), np.arange(3, dtype=np.int64),
                 np.array(255, np.uint8)),
        "flags": [np.array([True, False]), None],
        "nested": {"deep": [({"x": np.zeros((1, 2), np.int16)},)]},
    }
    out, _ = _roundtrip(tree)
    _tree_equal(tree, out)
    assert out["flags"][1] is None
    assert isinstance(out["ints"], tuple)
    assert isinstance(out["nested"]["deep"][0], tuple)
    assert out["bf16"].dtype == jnp.bfloat16


def test_codec_empty_leaves_and_containers():
    tree = {"empty1d": np.zeros((0,), np.float32),
            "empty3d": np.zeros((3, 0, 2), np.int32),
            "scalar": np.float64(3.5),
            "emptydict": {}, "emptylist": [], "none": None}
    out, buf = _roundtrip(tree)
    _tree_equal(tree, out)
    assert out["emptydict"] == {} and out["emptylist"] == []
    assert out["none"] is None
    # empty payload entries contribute zero bytes but keep shape/dtype
    assert out["empty3d"].shape == (3, 0, 2)


def test_codec_abstract_sizing_matches_concrete():
    """encoded_nbytes prices a message exactly from eval_shape — the
    dry-run / comm-overhead path for models too big to materialize."""
    # float32 throughout: eval_shape re-types leaves under jax's default
    # x64-disabled config, and the point here is size parity
    tree = {"w": np.zeros((8, 4), np.float32),
            "b": np.zeros((4,), np.float32)}
    abstract = jax.eval_shape(lambda: tree)
    assert codec.encoded_nbytes(abstract) == len(codec.encode(tree))


def test_codec_rejects_bad_input():
    with pytest.raises(ValueError):
        codec.decode(b"NOPE" + b"\x00" * 16)
    with pytest.raises(TypeError):
        codec.encode({"bad/key": np.zeros(1)})
    with pytest.raises(TypeError):
        codec.encode({1: np.zeros(1)})
    with pytest.raises(ValueError):
        codec.decode_update(codec.encode({"w": np.zeros(1)}))


def test_codec_version_header():
    """Every frame leads with magic + version; a frame speaking another
    version is refused with an error naming both versions, and the
    pre-versioning wire format (magic ``FKT1``) is rejected rather than
    misread."""
    buf = codec.encode({"w": np.zeros((2,), np.float32)})
    assert buf[:3] == codec.MAGIC and buf[3] == codec.VERSION
    tampered = buf[:3] + bytes([codec.VERSION + 1]) + buf[4:]
    with pytest.raises(ValueError, match=f"v{codec.VERSION + 1}"):
        codec.decode(tampered)
    with pytest.raises(ValueError, match="version"):
        codec.decode(b"FKT1" + buf[4:])


def test_codec_empty_gap_trace():
    """A party whose queries produced no clean gaps (e.g. zero teachers
    answered) still round-trips: the empty trace survives with shape and
    dtype intact and prices at zero payload bytes."""
    upd = PartyUpdate(party_id=3,
                      student_states=[{"w": np.ones((2, 2), np.float32)}],
                      vote_gaps=np.zeros((0,), np.float64),
                      num_examples=5, meta={"num_teachers": 0})
    dec = codec.decode_update(codec.encode_update(upd))
    assert dec.vote_gaps.shape == (0,)
    assert dec.vote_gaps.dtype == np.float64
    assert dec.wire_bytes() == upd.wire_bytes() == \
        pytree_bytes(upd.student_states)


def test_codec_zero_length_label_payload():
    """An empty vote answer (query_fraction rounding to zero on a tiny
    shard) frames, prices, and decodes cleanly."""
    msg = TokenLabels(party_id=1, labels=np.zeros((0,), np.int32))
    buf = codec.encode_labels(msg)
    assert codec.labels_encoded_nbytes(msg) == len(buf)
    dec = codec.decode_labels(buf)
    assert dec.labels.shape == (0,) and dec.labels.dtype == np.int32
    assert dec.party_id == 1 and msg.wire_bytes() == 0


def test_codec_truncated_frames_always_raise():
    """EVERY strict prefix of a frame raises ValueError — truncation in
    the magic, the version, the header length, the header JSON, or the
    payload is detected, never mis-parsed into a wrong tree."""
    upd = PartyUpdate(party_id=0,
                      student_states=[{"w": np.arange(4, dtype=np.float32)}],
                      vote_gaps=np.arange(3, dtype=np.float64),
                      num_examples=9, meta={"num_teachers": 1})
    buf = codec.encode_update(upd)
    for n in range(len(buf)):
        with pytest.raises(ValueError):
            codec.decode(buf[:n])
    # the untruncated frame still decodes (the loop above is strict)
    assert codec.decode_update(buf).party_id == 0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_codec_roundtrip_property(seed, depth):
    """Random nested dict/list/tuple trees over random dtypes/shapes
    (including empty dims) survive encode∘decode bit-for-bit."""
    rng = np.random.default_rng(seed)
    dtypes = [np.float32, np.float64, np.int32, np.int64, np.uint8,
              np.float16, bool]

    def leaf():
        shape = tuple(int(d) for d in
                      rng.integers(0, 4, size=rng.integers(0, 3)))
        dt = dtypes[rng.integers(len(dtypes))]
        return (rng.integers(0, 2, size=shape).astype(dt) if dt is bool
                else rng.normal(0, 1, size=shape).astype(dt))

    def build(d):
        if d == 0 or rng.random() < 0.3:
            return leaf()
        kind = rng.integers(4)
        n = int(rng.integers(0, 3))
        if kind == 0:
            return {f"k{i}": build(d - 1) for i in range(n)}
        if kind == 1:
            return [build(d - 1) for _ in range(n)]
        if kind == 2:
            return tuple(build(d - 1) for _ in range(n))
        return None

    tree = {"root": build(depth)}
    out, _ = _roundtrip(tree)
    _tree_equal(tree, out)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from(["nn", "rf", "gbdt", "lm", "custom-learner",
                        None]),
       st.integers(1, 4))
def test_codec_mixed_learner_update_roundtrip_property(seed, kind,
                                                       n_students):
    """Heterogeneous-wire contract: a PartyUpdate from ANY learner
    family — arbitrary state pytrees, arbitrary declared kind,
    including undeclared (None) and unregistered custom kinds —
    round-trips through the codec with its learner_kind, states, gap
    trace, and framed size intact."""
    rng = np.random.default_rng(seed)
    # one shape per family-ish pytree: dense float stacks (nn), int
    # split/leaf tables (trees), scalars
    states = []
    for _ in range(n_students):
        states.append({
            "w": rng.normal(0, 1, (int(rng.integers(1, 5)), 3)
                            ).astype(np.float32),
            "splits": rng.integers(0, 7, int(rng.integers(0, 6))
                                   ).astype(np.int32),
            "bias": np.float64(rng.normal()),
        })
    upd = PartyUpdate(
        party_id=int(rng.integers(0, 1000)),
        student_states=states,
        vote_gaps=rng.normal(0, 1, int(rng.integers(0, 9))
                             ).astype(np.float32),
        num_examples=int(rng.integers(0, 10**6)),
        learner_kind=kind,
        meta={"num_query_labels": int(rng.integers(0, 100))})
    buf = codec.encode_update(upd)
    assert codec.update_encoded_nbytes(upd) == len(buf)
    out = codec.decode_update(buf)
    assert out.party_id == upd.party_id
    assert out.learner_kind == kind
    assert out.num_examples == upd.num_examples
    np.testing.assert_array_equal(out.vote_gaps, upd.vote_gaps)
    _tree_equal(out.student_states, upd.student_states)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from(["nn", "rf", "lm", None]),
       st.sampled_from(["example", "token", None]),
       st.integers(1, 500), st.integers(1, 70),
       st.sampled_from([None, "fp", "names"]))
def test_codec_domain_header_roundtrip_property(seed, kind, unit, T, U,
                                                flavor):
    """The vote-domain wire contract: a PartyUpdate declaring ANY
    VoteDomain — either unit, any (T, U), fingerprinted or anonymous,
    with or without label_names — round-trips through the codec with
    the domain's full identity key AND its learner_kind intact;
    undeclared (None) stays None."""
    from repro.federation import VoteDomain

    rng = np.random.default_rng(seed)
    dom = None
    if unit is not None:
        dom = VoteDomain(
            unit=unit, num_units=T, num_classes=U,
            fingerprint=(f"{rng.integers(2**32):08x}"
                         if flavor == "fp" else None),
            label_names=(tuple(f"c{i}" for i in range(U))
                         if flavor == "names" and U <= 8 else None))
    upd = PartyUpdate(
        party_id=int(rng.integers(0, 100)),
        student_states=[{"w": rng.normal(0, 1, (2, 3)
                                         ).astype(np.float32)}],
        vote_gaps=rng.normal(0, 1, 4).astype(np.float32),
        num_examples=int(rng.integers(1, 100)),
        learner_kind=kind, domain=dom,
        meta={"num_query_labels": T})
    buf = codec.encode_update(upd)
    assert codec.update_encoded_nbytes(upd) == len(buf)
    out = codec.decode_update(buf)
    assert out.learner_kind == kind
    if dom is None:
        assert out.domain is None
    else:
        assert out.domain == dom and out.domain.key == dom.key
        assert out.domain.label_names == dom.label_names


def test_codec_legacy_frame_decodes_to_no_domain():
    """A pre-domain peer at the SAME codec version never sets the
    header's "domain" key at all (not even to null).  Such a frame must
    decode to domain=None — the "undeclared" sentinel the aggregate
    resolves from the party's binding — with every other field intact."""
    states = [{"w": np.arange(6, dtype=np.float32).reshape(2, 3)}]
    gaps = np.arange(3, dtype=np.float64)
    legacy_header = {"kind": "PartyUpdate", "party_id": 7,
                     "num_examples": 42, "learner_kind": "rf",
                     "meta": {"num_teachers": 2}}   # no "domain" key
    buf = codec.encode({"student_states": states, "vote_gaps": gaps},
                       legacy_header)
    out = codec.decode_update(buf)
    assert out.domain is None
    assert out.party_id == 7 and out.learner_kind == "rf"
    assert out.num_examples == 42
    np.testing.assert_array_equal(out.vote_gaps, gaps)
    _tree_equal(out.student_states, states)
    # and a same-version frame that DOES declare is byte-compatible:
    # only the header field differs
    assert buf[:4] == codec.encode_update(PartyUpdate(
        party_id=7, student_states=states, vote_gaps=gaps,
        num_examples=42, learner_kind="rf",
        meta={"num_teachers": 2}))[:4]


def test_codec_domain_frame_truncation_sweep():
    """EVERY strict prefix of a domain-extended frame raises — the
    header grew (domain + learner_kind ride in it), so the truncation
    guarantee is re-proved over the extended header, not grandfathered
    from the pre-domain frame layout."""
    from repro.federation import VoteDomain

    upd = PartyUpdate(
        party_id=1,
        student_states=[{"w": np.arange(4, dtype=np.float32)}],
        vote_gaps=np.arange(3, dtype=np.float64), num_examples=9,
        learner_kind="nn",
        domain=VoteDomain("example", 8, 2, fingerprint="deadbeef",
                          label_names=("neg", "pos")),
        meta={"num_teachers": 1})
    buf = codec.encode_update(upd)
    for n in range(len(buf)):
        with pytest.raises(ValueError):
            codec.decode(buf[:n])
    out = codec.decode_update(buf)          # the full frame is intact
    assert out.domain == upd.domain
    assert out.domain.label_names == ("neg", "pos")


# ---------------------------------------------------------------------------
# CRC trailer (codec v3)
# ---------------------------------------------------------------------------
def _crc_test_frame():
    upd = PartyUpdate(
        party_id=1,
        student_states=[{"w": np.arange(4, dtype=np.float32)}],
        vote_gaps=np.arange(3, dtype=np.float64), num_examples=9,
        learner_kind="nn", meta={"num_teachers": 1})
    return codec.encode_update(upd)


def test_codec_crc_trailer_detects_every_single_byte_flip():
    """No single corrupted byte anywhere in a frame decodes silently:
    magic/version damage is a codec error, header/payload/trailer
    damage trips the crc32 trailer.  This is the property the socket
    coordinator's NAK-with-reason-``corrupt`` path stands on
    (tests/test_faults.py exercises it over a real wire)."""
    buf = _crc_test_frame()
    for k in range(len(buf)):
        flipped = buf[:k] + bytes([buf[k] ^ 0xFF]) + buf[k + 1:]
        with pytest.raises(ValueError):
            codec.decode(flipped)
    assert codec.decode_update(buf).party_id == 1   # strict loop above


def test_codec_corruption_raises_typed_errors():
    """The coordinator maps refusals to NAK reasons by exception type,
    so the types are wire contract: corruption/truncation are
    CorruptFrameError/TruncatedFrameError, an alien version is
    VersionMismatchError, and all are CodecError ⊂ ValueError (old
    ``except ValueError`` callers still catch everything)."""
    buf = _crc_test_frame()
    with pytest.raises(codec.CorruptFrameError, match="crc32"):
        codec.decode(buf[:-1] + bytes([buf[-1] ^ 0x01]))
    with pytest.raises(codec.TruncatedFrameError):
        codec.decode(buf[:-1])
    with pytest.raises(codec.CorruptFrameError, match="trailing"):
        codec.decode(buf + b"\x00")
    with pytest.raises(codec.VersionMismatchError):
        codec.decode(buf[:3] + bytes([codec.VERSION + 1]) + buf[4:])
    for exc in (codec.CorruptFrameError, codec.TruncatedFrameError,
                codec.VersionMismatchError):
        assert issubclass(exc, codec.CodecError)
        assert issubclass(exc, ValueError)


def test_codec_v2_frame_still_decodes():
    """Version-bump compatibility: a v2 peer's frame (no crc trailer)
    is the same bytes minus the trailer with version byte 2 — it must
    decode to the identical update, and the pricing helper must agree
    with the v3 trailer it now includes."""
    buf = _crc_test_frame()
    v2 = buf[:3] + bytes([2]) + buf[4:-4]      # strip the crc trailer
    out = codec.decode_update(v2)
    assert out.party_id == 1 and out.num_examples == 9
    np.testing.assert_array_equal(out.vote_gaps,
                                  np.arange(3, dtype=np.float64))
    # a v2 frame with slack bytes is NOT tolerated: the downgrade path
    # must never become a crc bypass
    with pytest.raises(ValueError):
        codec.decode(v2 + b"\x00\x00\x00\x00")


# ---------------------------------------------------------------------------
# Wire accounting
# ---------------------------------------------------------------------------
def test_update_wire_bytes_counts_gap_trace():
    """The L1 accounting bug: vote_gaps ride in the same message as the
    student states, so wire_bytes must count both — and must equal the
    codec's measured payload exactly (framed size adds only header)."""
    states = [{"w": np.zeros((4, 2), np.float32)}]
    gaps = np.arange(16, dtype=np.float64)
    upd = PartyUpdate(party_id=0, student_states=states, vote_gaps=gaps,
                      num_examples=10, meta={"num_teachers": 2})
    assert upd.wire_bytes() == pytree_bytes(states) + gaps.nbytes
    buf = codec.encode_update(upd)
    measured = len(buf)
    assert codec.update_encoded_nbytes(upd) == measured
    # framed = header + payload; payload is exactly the accounted bytes
    header_overhead = measured - upd.wire_bytes()
    assert 0 < header_overhead < 4096
    dec = codec.decode_update(buf)
    assert dec.party_id == 0 and dec.num_examples == 10
    assert dec.meta["num_teachers"] == 2
    assert dec.wire_bytes() == upd.wire_bytes()
    _tree_equal(upd.student_states, dec.student_states)
    np.testing.assert_array_equal(upd.vote_gaps, dec.vote_gaps)


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------
L2_CFG = dict(num_parties=3, num_partitions=1, num_subsets=2,
              num_classes=2, privacy_level="L2", gamma=0.1,
              query_fraction=0.5, seed=7)


def test_thread_transport_matches_inprocess(data, learner):
    """Transport smoke (tier-1): parallel parties over a thread pool are
    bit-identical to the serial in-process round at a fixed seed."""
    cfg = FedKTConfig(**L2_CFG)
    ref = FedKTSession(learner, data, cfg, engine="loop").run()
    par = FedKTSession(learner, data, cfg, engine="loop",
                       transport="thread", parallelism=3).run()
    assert par.accuracy == ref.accuracy
    assert par.epsilon == ref.epsilon
    _tree_equal(par.student_states, ref.student_states)
    assert par.meta["wire_bytes"] == ref.meta["wire_bytes"]
    assert par.meta["transport"] == "thread"
    assert par.meta["wire_bytes"]["updates"] > \
        par.meta["wire_bytes"]["updates_payload"] > 0


def test_subprocess_transport_matches_inprocess(data, learner):
    """Acceptance: transport="subprocess" (one spawned interpreter per
    party, PartyUpdate crossing as codec bytes) returns bit-identical
    accuracy AND epsilon to the in-process loop engine."""
    cfg = FedKTConfig(**L2_CFG)
    ref = FedKTSession(learner, data, cfg, engine="loop").run()
    sub = FedKTSession(learner, data, cfg, engine="loop",
                       transport="subprocess", parallelism=2).run()
    assert sub.accuracy == ref.accuracy
    assert sub.epsilon == ref.epsilon
    _tree_equal(sub.student_states, ref.student_states)
    assert sub.meta["wire_bytes"] == ref.meta["wire_bytes"]


def test_transports_agree_across_engines_and_learners(data):
    """Engine x transport grid on a tree learner: the vmap engine under
    a parallel transport still reproduces the serial loop exactly."""
    cfg = FedKTConfig(num_parties=2, num_partitions=2, num_subsets=2,
                      num_classes=2, seed=3)
    lrn = RFLearner(num_classes=2, num_trees=3, depth=2)
    ref = FedKTSession(lrn, data, cfg, engine="loop").run()
    par = FedKTSession(lrn, data, cfg, engine="vmap",
                       transport="thread").run()
    assert par.accuracy == ref.accuracy
    _tree_equal(par.student_states, ref.student_states)


def test_get_transport_registry():
    assert get_transport("inprocess").name == "inprocess"
    assert get_transport("thread", 4).parallelism == 4
    assert get_transport("subprocess").name == "subprocess"
    assert get_transport("socket", 4).name == "socket"
    t = ThreadTransport(parallelism=2)
    assert get_transport(t) is t
    with pytest.raises(ValueError):
        get_transport("carrier-pigeon")
    with pytest.raises(ValueError):
        get_transport(InProcessTransport(), parallelism=2)


# ---------------------------------------------------------------------------
# Cleanup contract
# ---------------------------------------------------------------------------
def test_transports_are_context_managers():
    """Every transport supports ``with`` and idempotent close."""
    for name in ("inprocess", "thread", "subprocess", "socket"):
        with get_transport(name) as t:
            assert t.name == name
        t.close()


def test_subprocess_cleanup_on_party_failure(data, learner):
    """Regression: a party that raises mid-round must not leak worker
    interpreters.  The old executor-based round kept the remaining
    spawned processes alive (still training dropped parties) after the
    session had already failed; the pool is now terminated in-place."""
    cfg = FedKTConfig(**L2_CFG)
    shards = [np.arange(0, 100), np.arange(100, 200),
              np.array([10 ** 9])]          # out-of-range: party 2 dies
    session = FedKTSession(learner, data, cfg, engine="loop",
                           party_indices=shards,
                           transport="subprocess", parallelism=3)
    before = set(multiprocessing.active_children())
    with pytest.raises(IndexError):
        session.run()
    # terminate() + join() ran in the round's finally: no spawned
    # worker outlives the failure
    leaked = [p for p in multiprocessing.active_children()
              if p not in before]
    assert leaked == []


def test_thread_cleanup_on_party_failure(data, learner):
    """The thread transport's failed round raises promptly (queued
    parties are cancelled) and the session object stays reusable."""
    cfg = FedKTConfig(**L2_CFG)
    shards = [np.array([10 ** 9]), np.arange(0, 100),
              np.arange(100, 200)]
    with ThreadTransport(parallelism=1) as transport:
        session = FedKTSession(learner, data, cfg, engine="loop",
                               party_indices=shards, transport=transport)
        with pytest.raises(IndexError):
            session.run()
