"""Per-architecture smoke tests (assignment deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED
same-family variant, run one forward + one training step on CPU, assert
output shapes and finiteness; plus decode-vs-full-forward consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import lm_batch as _batch
from repro.configs import ARCH_IDS, TrainConfig, get_smoke
from repro.configs.base import MoEConfig
from repro.core.distill import make_train_step
from repro.models import Model

# 10 architectures x (forward + train + decode): the single largest
# CPU cost in the suite — scheduled full run only
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 3
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S)

    # forward: prediction shapes
    preds = model.predict(params, batch)
    assert preds.shape == (B, S)
    assert int(preds.max()) < cfg.vocab_size

    # one train step: loss finite, params updated, no NaNs anywhere
    tcfg = TrainConfig(batch_size=B, seq_len=S, steps=10)
    step, opt = make_train_step(model, tcfg)
    step = jax.jit(step)
    new_params, opt_state, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch
    # something must have changed
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = get_smoke(arch).replace(dtype="float32", param_dtype="float32")
    if cfg.moe:  # disable capacity drops for exactness
        cfg = cfg.replace(moe=MoEConfig(
            num_experts=cfg.moe.num_experts, top_k=cfg.moe.top_k,
            num_shared_experts=cfg.moe.num_shared_experts,
            capacity_factor=8.0, first_k_dense=cfg.moe.first_k_dense,
            dense_ff_mult=cfg.moe.dense_ff_mult))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)),
                       jnp.int32)
    extra = _batch(cfg, B, S + 1)
    extra.pop("tokens"), extra.pop("labels")

    full, _ = model.logits(params, {"tokens": toks, **extra}, mode="train")
    h, cache, _ = model.hidden(params, {"tokens": toks[:, :S], **extra},
                               mode="prefill")

    cache = model.grow_cache(cache, 8)
    pos = S + (cfg.frontend_embeds
               if cfg.frontend_embeds and not cfg.is_encoder_decoder else 0)
    lg, _ = model.logits(params, {"tokens": toks[:, S:S + 1]},
                         mode="decode", cache=cache, pos=jnp.int32(pos))
    err = float(jnp.abs(lg[:, 0] - full[:, S]).max())
    assert err < 1e-4, f"{arch}: decode/train mismatch {err}"


def test_ring_cache_matches_full_window_cache():
    """Sliding-window ring buffer decode == full-length cache decode."""
    cfg = get_smoke("mixtral-8x7b").replace(
        dtype="float32", param_dtype="float32", window=16,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=8.0))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 40
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)),
                       jnp.int32)
    full, _ = model.logits(params, {"tokens": toks}, mode="train")

    # decode from scratch via ring cache (window 16 < S)
    cache = model.init_cache(B, S + 1)
    lg = None
    for t in range(S + 1):
        lg, cache = model.logits(params, {"tokens": toks[:, t:t + 1]},
                                 mode="decode", cache=cache,
                                 pos=jnp.int32(t))
    err = float(jnp.abs(lg[:, 0] - full[:, S]).max())
    assert err < 1e-4, f"ring cache mismatch {err}"


@pytest.mark.parametrize("prompt_minus_window", [32, 0, -32],
                         ids=["longer", "equal", "shorter"])
def test_prefill_vs_window_decode_matches_full_forward(
        prompt_minus_window):
    """Regression for the sliding-window cache-growth bug: a prefill
    LONGER than the window used to leave the ATTN_LOCAL cache linear at
    prompt length, so decode writes at absolute pos clamped out of
    bounds (silently wrong logits, ~0.15 divergence on the gemma2
    smoke).  grow_cache now shrinks the over-long linear cache into a
    ``window``-slot ring (last window keys, slot order p % window).
    The == / < window cases pin the pre-existing grow path."""
    cfg = get_smoke("gemma2-27b").replace(dtype="float32",
                                          param_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, G = 1, 6
    S = cfg.window + prompt_minus_window     # 96 / 64 / 32 vs window 64
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + G)),
                       jnp.int32)
    full, _ = model.logits(params, {"tokens": toks}, mode="train")
    _, cache, _ = model.hidden(params, {"tokens": toks[:, :S]},
                               mode="prefill")
    cache = model.grow_cache(cache, G)
    for i in range(G):
        lg, cache = model.logits(params, {"tokens": toks[:, S+i:S+i+1]},
                                 mode="decode", cache=cache,
                                 pos=jnp.int32(S + i))
        err = float(jnp.abs(lg[:, 0] - full[:, S + i]).max())
        assert err < 1e-4, f"decode step {i}: mismatch {err}"
    # the local caches are window-bounded rings while the global caches
    # grew to the full prompt + decode length
    from repro.models.layers import ATTN_CACHE_LEN_AXIS
    lens = {leaf.shape[leaf.ndim + ATTN_CACHE_LEN_AXIS]
            for leaf in jax.tree.leaves(cache) if leaf.ndim >= 4}
    assert lens == {min(S + G, cfg.window), S + G}


def test_moe_dispatch_matches_dense_oracle():
    """Capacity dispatch == dense all-experts oracle when no drops."""
    from repro.models import moe as M
    cfg = get_smoke("deepseek-moe-16b").replace(
        dtype="float32", param_dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                      capacity_factor=8.0))
    p = M.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = M.moe_apply(cfg, p, x)
    y_ref = M.moe_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) >= 0


def test_moe_capacity_drops_bounded():
    """With cf=1.0 and adversarial routing, output stays finite and the
    drop path zeroes (never corrupts) overflowing tokens."""
    from repro.models import moe as M
    cfg = get_smoke("mixtral-8x7b").replace(
        dtype="float32", param_dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=1.0))
    p = M.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y, _ = M.moe_apply(cfg, p, x)
    assert np.isfinite(np.asarray(y)).all()


def test_long_context_variant_is_subquadratic():
    from repro.configs import get_config, long_context_variant
    from repro.configs.base import ATTN
    for arch in ARCH_IDS:
        if arch == "whisper-tiny":
            continue
        cfg = long_context_variant(get_config(arch))
        assert all(k != ATTN for k in cfg.pattern), arch
