"""Privacy accountant: theorem bounds, monotonicity, composition."""
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import privacy as P


def test_lemma7_decreases_with_gap():
    gaps = np.array([0.0, 1.0, 5.0, 20.0, 100.0])
    q = P.lemma7_q(gaps, gamma=0.1, num_classes=2)
    assert (np.diff(q) <= 1e-12).all()
    assert q[0] <= 1.0 and q[-1] < 1e-3


def test_lemma7_exact_matches_top2_bound_binary():
    """For u=2 the top-2 bound and the exact histogram bound coincide."""
    counts = np.array([[7, 3], [5, 5], [10, 0]])
    gaps = counts.max(1) - np.sort(counts, 1)[:, -2]
    q_top2 = P.lemma7_q(gaps, 0.2, 2)
    q_exact = P.lemma7_q_exact(counts, 0.2)
    np.testing.assert_allclose(q_top2, q_exact, rtol=1e-9)


@given(st.floats(0.01, 0.2), st.integers(1, 3), st.integers(1, 50),
       st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_eps_monotone_in_queries(gamma, s, T, seed):
    rng = np.random.default_rng(seed)
    gaps = rng.integers(0, 10, T).astype(float)
    e1 = P.fedkt_l1_epsilon(gaps, gamma, s, num_classes=4)
    e2 = P.fedkt_l1_epsilon(np.concatenate([gaps, gaps]), gamma, s,
                            num_classes=4)
    assert e2 >= e1 - 1e-9


def test_eps_monotone_in_gamma():
    gaps = np.full(50, 3.0)
    es = [P.fedkt_l1_epsilon(gaps, g, s=2, num_classes=4)
          for g in (0.02, 0.05, 0.1, 0.2)]
    assert all(a <= b + 1e-9 for a, b in zip(es, es[1:]))


def test_l1_exact_at_most_gap_bound():
    """The exact Lemma-7 path (full clean histograms) is never looser
    than the top-2 gap bound at the same (gamma, s); on BINARY
    histograms the two coincide (the single o != o* term IS the top-2
    gap term)."""
    rng = np.random.default_rng(7)
    gamma, s, T = 0.1, 2, 40
    # binary: equality
    counts2 = rng.multinomial(3 * s, [0.5, 0.5], size=T) * s
    gaps2 = counts2.max(1) - np.sort(counts2, 1)[:, -2]
    e_exact = P.fedkt_l1_epsilon(counts2, gamma, s, 2, exact=True)
    e_gap = P.fedkt_l1_epsilon(gaps2, gamma, s, 2)
    assert abs(e_exact - e_gap) < 1e-9
    # multiclass: exact is at least as tight
    counts4 = rng.multinomial(5 * s, [0.4, 0.3, 0.2, 0.1], size=T) * s
    gaps4 = counts4.max(1) - np.sort(counts4, 1)[:, -2]
    e_exact4 = P.fedkt_l1_epsilon(counts4, gamma, s, 4, exact=True)
    e_gap4 = P.fedkt_l1_epsilon(gaps4, gamma, s, 4)
    assert e_exact4 <= e_gap4 + 1e-9


def test_moments_tighter_than_advanced_composition():
    """Paper §B.7: the data-dependent accountant beats advanced
    composition (e.g. cod-rna: 11.2 vs 20.2)."""
    gamma, s, T = 0.1, 1, 90
    gaps = np.full(T, 4.0)      # modest gaps
    eps_ma = P.fedkt_l1_epsilon(gaps, gamma, s, num_classes=2)
    eps_adv = P.advanced_composition(2 * s * gamma, T, delta_slack=1e-5)
    assert eps_ma < eps_adv


def test_l2_parallel_composition_is_max():
    g1 = np.full(20, 2.0)
    g2 = np.full(40, 0.5)       # worse gaps, more queries
    e_single = P.fedkt_l2_epsilon([g2], 0.05, 2)
    e_both = P.fedkt_l2_epsilon([g1, g2], 0.05, 2)
    assert abs(e_both - max(
        P.fedkt_l2_epsilon([g1], 0.05, 2), e_single)) < 1e-9


def test_theorem5_bound_used_when_q_large():
    """When q exceeds the Thm-6 validity region, the Thm-5 (data-
    independent) moment bound must kick in and stay finite."""
    alpha = P.per_query_moments(np.array([0.9]), eps0=0.4)
    assert np.isfinite(alpha).all()
    lam = P.LAMBDAS
    np.testing.assert_allclose(
        alpha[0], (0.4 ** 2 / 2) * lam * (lam + 1))


def test_tail_bound_conversion():
    # k identical queries with the data-independent bound:
    # alpha(l) = k * eps0^2/2 * l(l+1); eps = min_l (alpha + ln(1/d))/l
    k, eps0, delta = 100, 0.1, 1e-5
    alpha = P.per_query_moments(np.full(k, 1.0), eps0).sum(0)
    eps = P.moments_to_eps(alpha, delta)
    lam = P.LAMBDAS
    expected = np.min((k * eps0 ** 2 / 2 * lam * (lam + 1)
                       + np.log(1 / delta)) / lam)
    assert abs(eps - expected) < 1e-9
