"""Sharding rules: spec validity, divisibility fallbacks, constrain."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke
from repro.models import Model
from repro.sharding import specs as S


def _mesh(shape=(2, 2), axes=("data", "model")):
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        pytest.skip("needs multiple devices")
    return jax.make_mesh(shape, axes)


def test_spec_for_param_rules():
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # column weight: output dim on model (divisible by 1 trivially)
    assert S.spec_for_param(("periods", "b0", "attn", "wq"),
                            (2, 64, 128), mesh) == P(None, "data", "model")
    assert S.spec_for_param(("x", "wo"), (2, 128, 64),
                            mesh) == P(None, "model", "data")
    assert S.spec_for_param(("embed", "table"), (512, 64),
                            mesh) == P("model", "data")
    assert S.spec_for_param(("norm1", "scale"), (64,), mesh) == P()


def test_spec_divisibility_fallback():
    """Axes that don't divide the dim are dropped, never invalid."""
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    # 24 heads * 128 dh = 3072 divides 16; 10 does not
    sp = S._spec((10, 7), FakeMesh, model_dim=-1, data_dim=-2)
    assert sp == P(None, None)
    sp = S._spec((32, 3072), FakeMesh, model_dim=-1, data_dim=-2)
    assert sp == P("data", "model")


def test_constrain_noop_without_mesh():
    S.set_activation_mesh(None)
    x = jnp.ones((4, 4))
    assert S.constrain(x, "data", None) is x


def test_param_shardings_cover_full_tree():
    cfg = get_smoke("mixtral-8x7b")
    model = Model(cfg)
    pshapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    shardings = S.param_shardings(pshapes, mesh)
    assert jax.tree.structure(shardings) == jax.tree.structure(pshapes)


def test_cache_sharding_finds_batch_dim():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    cache = {"k": jax.ShapeDtypeStruct((3, 8, 128, 16, 64), jnp.bfloat16)}
    sh = S.cache_sharding(cache, mesh, batch_size=8)
    # single-device mesh: everything valid; structure preserved
    assert jax.tree.structure(sh) == jax.tree.structure(cache)
