"""Federation API: seed-for-seed reproducibility, engine agreement,
strategies, protocol messages."""
import jax
import numpy as np
import pytest

from repro.configs.base import FedKTConfig
from repro.core.learners import GBDTLearner, NNLearner, RFLearner
from repro.core.partition import homogeneous_partition
from repro.data.synthetic import tabular_binary
from repro.federation import (CentralPATEStrategy, FedKTSession,
                              LoopEngine, PartyBinding, PartyUpdate,
                              ResolvedBinding, SoloStrategy,
                              StreamingVoteAggregate, VmapEngine,
                              get_engine, label_wire_bytes, learner_kind,
                              pytree_bytes, query_budget)
from repro.federation.party import Party
from repro.models.smallnets import MLP


@pytest.fixture(scope="module")
def data():
    # n=2048 -> 1536 train examples: halves/quarters stay pow2-aligned
    # so loop and vmap engines share identical padding buckets
    return tabular_binary(n=2048, seed=0)


@pytest.fixture(scope="module")
def learner():
    return NNLearner(MLP(14, 2, hidden=16), num_classes=2, steps=60)


def _tree_equal(a, b):
    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(leaves_a) == len(leaves_b)
    for la, lb in zip(leaves_a, leaves_b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# Recorded from the legacy ``run_fedkt`` entry point (deleted this PR)
# on the exact config below — the loop engine reproduced it bit-for-bit
# through PR 1/2/3, including the transport-layer codec round-trip.
LEGACY_ACCURACY = 0.50390625
LEGACY_EPSILON = 13.436462732485094


def test_session_loop_matches_recorded_legacy_run(data, learner):
    """The acceptance contract: engine="loop" reproduces the (now
    removed) run_fedkt entry point's accuracy AND epsilon at a fixed
    seed, against the recorded expectation."""
    cfg = FedKTConfig(num_parties=3, num_partitions=1, num_subsets=2,
                      num_classes=2, privacy_level="L2", gamma=0.1,
                      query_fraction=0.5, seed=7)
    res = FedKTSession(learner, data, cfg, engine="loop").run()
    assert res.accuracy == LEGACY_ACCURACY
    assert res.epsilon == pytest.approx(LEGACY_EPSILON, rel=1e-9)


def test_loop_and_vmap_engines_agree(data, learner):
    """Same protocol, same PRNG schedule, same votes: with pow2-aligned
    party shards the two engines match down to the student weights."""
    cfg = FedKTConfig(num_parties=2, num_partitions=2, num_subsets=2,
                      num_classes=2, seed=3)
    parts = homogeneous_partition(len(data["y_train"]), 2, seed=3)
    r_loop = FedKTSession(learner, data, cfg, engine="loop",
                          party_indices=parts).run()
    r_vmap = FedKTSession(learner, data, cfg, engine="vmap",
                          party_indices=parts).run()
    assert r_loop.accuracy == r_vmap.accuracy
    _tree_equal(r_loop.student_states, r_vmap.student_states)


def test_party_engines_produce_identical_updates(data, learner):
    cfg = FedKTConfig(num_parties=1, num_partitions=2, num_subsets=2,
                      num_classes=2, seed=11)
    idx = np.arange(512)
    party = Party(party_id=0, X=data["X_train"], y=data["y_train"],
                  indices=idx, cfg=cfg, learner=learner,
                  student_learner=learner)
    key = jax.random.PRNGKey(0)
    upd_l, key_l = party.local_round(key, data["X_public"], 128,
                                     LoopEngine())
    upd_v, key_v = party.local_round(key, data["X_public"], 128,
                                     VmapEngine())
    np.testing.assert_array_equal(np.asarray(key_l), np.asarray(key_v))
    np.testing.assert_array_equal(upd_l.vote_gaps, upd_v.vote_gaps)
    _tree_equal(upd_l.student_states, upd_v.student_states)
    assert upd_l.wire_bytes() == upd_v.wire_bytes() > 0


@pytest.mark.parametrize("make_learner", [
    lambda: RFLearner(num_classes=2, num_trees=4, depth=3),
    lambda: GBDTLearner(num_rounds=6, depth=3),
], ids=["rf", "gbdt"])
def test_tree_engines_agree_on_quickstart(data, make_learner):
    """Acceptance: engine="vmap" with the tree learners reproduces the
    loop engine's vote labels on the quickstart federation shape — and
    because stacked tree fits are bit-identical under zero-weight
    padding, the students and the final model match exactly too."""
    learner = make_learner()
    cfg = FedKTConfig(num_parties=5, num_partitions=2, num_subsets=4,
                      num_classes=2, beta=0.5, seed=0)
    r_loop = FedKTSession(learner, data, cfg, engine="loop").run()
    r_vmap = FedKTSession(learner, data, cfg, engine="vmap").run()
    assert r_loop.accuracy == r_vmap.accuracy
    _tree_equal(r_loop.student_states, r_vmap.student_states)
    _tree_equal(r_loop.final_state, r_vmap.final_state)


def test_tree_party_update_identical_across_engines(data):
    """Party-level: identical vote gaps and student states for an
    RFLearner party under loop vs vmap engines."""
    learner = RFLearner(num_classes=2, num_trees=4, depth=3)
    cfg = FedKTConfig(num_parties=1, num_partitions=2, num_subsets=2,
                      num_classes=2, seed=11)
    party = Party(party_id=0, X=data["X_train"], y=data["y_train"],
                  indices=np.arange(512), cfg=cfg, learner=learner,
                  student_learner=learner)
    key = jax.random.PRNGKey(0)
    upd_l, _ = party.local_round(key, data["X_public"], 128, LoopEngine())
    upd_v, _ = party.local_round(key, data["X_public"], 128, VmapEngine())
    np.testing.assert_array_equal(upd_l.vote_gaps, upd_v.vote_gaps)
    _tree_equal(upd_l.student_states, upd_v.student_states)
    assert upd_l.wire_bytes() == upd_v.wire_bytes() > 0


@pytest.mark.parametrize("make_learner,engine", [
    (lambda: NNLearner(MLP(14, 2, hidden=16), num_classes=2, steps=60),
     "loop"),
    (lambda: NNLearner(MLP(14, 2, hidden=16), num_classes=2, steps=60),
     "vmap"),
    (lambda: RFLearner(num_classes=2, num_trees=4, depth=3), "loop"),
    (lambda: RFLearner(num_classes=2, num_trees=4, depth=3), "vmap"),
    (lambda: GBDTLearner(num_rounds=6, depth=3), "loop"),
    (lambda: GBDTLearner(num_rounds=6, depth=3), "vmap"),
], ids=["nn-loop", "nn-vmap", "rf-loop", "rf-vmap", "gbdt-loop",
        "gbdt-vmap"])
def test_binding_api_matches_legacy_constructor(data, make_learner,
                                                engine):
    """The bindings refactor's regression contract: a homogeneous
    session expressed as explicit per-party bindings is bit-identical —
    students, final model, epsilon, accuracy — to the legacy
    single-learner constructor, for every learner family and engine.
    The L2 config exercises the epsilon path (per-party gap folding)
    too."""
    learner = make_learner()
    cfg = FedKTConfig(num_parties=3, num_partitions=1, num_subsets=2,
                      num_classes=2, privacy_level="L2", gamma=0.1,
                      query_fraction=0.5, seed=7)
    legacy = FedKTSession(learner, data, cfg, engine=engine).run()
    bindings = [PartyBinding(learner, engine=engine)
                for _ in range(cfg.num_parties)]
    bound = FedKTSession(bindings, data, cfg, engine=engine).run()
    assert bound.accuracy == legacy.accuracy
    assert bound.epsilon == legacy.epsilon
    _tree_equal(bound.student_states, legacy.student_states)
    _tree_equal(bound.final_state, legacy.final_state)
    assert (bound.meta["wire_bytes"]["per_party"]
            == legacy.meta["wire_bytes"]["per_party"])
    # the shorthand reports itself as per-party bindings, one identical
    # row per party
    kind = learner_kind(learner)
    assert legacy.meta["party_bindings"] == [
        {"learner": kind, "engine": engine}] * cfg.num_parties


def test_session_rejects_malformed_bindings(data, learner):
    cfg = FedKTConfig(num_parties=3, num_partitions=1, num_subsets=2,
                      num_classes=2, seed=0)
    with pytest.raises(ValueError, match="num_parties=3"):
        FedKTSession([PartyBinding(learner)] * 2, data, cfg)
    with pytest.raises(TypeError, match="PartyBinding"):
        FedKTSession([learner] * 3, data, cfg)
    with pytest.raises(ValueError, match="student_learner"):
        FedKTSession([PartyBinding(learner)] * 3, data, cfg,
                     student_learner=learner)


def test_fit_stacked_matches_serial_fit(learner):
    rng = np.random.default_rng(0)
    Xs = [rng.normal(0, 1, (40, 14)).astype(np.float32) for _ in range(3)]
    ys = [rng.integers(0, 2, 40).astype(np.int32) for _ in range(3)]
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    stacked = learner.fit_stacked(keys, Xs, ys)
    for i in range(3):
        serial = learner.fit(keys[i], Xs[i], ys[i])
        _tree_equal(serial, jax.tree.map(lambda a: a[i], stacked))
    # stacked predict rows == serial predict
    Xq = rng.normal(0, 1, (17, 14)).astype(np.float32)
    preds = np.asarray(learner.predict_stacked(stacked, Xq))
    for i in range(3):
        row = np.asarray(learner.predict(
            jax.tree.map(lambda a: a[i], stacked), Xq))
        np.testing.assert_array_equal(preds[i], row)


def test_baseline_strategies_run(data, learner):
    cfg = FedKTConfig(num_parties=2, num_partitions=1, num_subsets=2,
                      num_classes=2, seed=1)
    solo = SoloStrategy(learner).run(data, cfg)
    assert 0.0 <= solo.accuracy <= 1.0
    assert len(solo.meta["per_party"]) == cfg.num_parties
    pate = CentralPATEStrategy(learner, 2).run(data, cfg)
    assert 0.0 <= pate.accuracy <= 1.0


def test_query_budget_levels():
    n = 100
    l0 = FedKTConfig(privacy_level="L0", query_fraction=0.2)
    assert query_budget(l0, n) == (n, n)
    l1 = FedKTConfig(privacy_level="L1", query_fraction=0.2)
    assert query_budget(l1, n) == (n, 20)
    l2 = FedKTConfig(privacy_level="L2", query_fraction=0.2)
    assert query_budget(l2, n) == (20, n)
    tiny = FedKTConfig(privacy_level="L1", query_fraction=0.001)
    assert query_budget(tiny, n) == (n, 1)      # never zero queries


def test_engine_registry():
    assert get_engine("loop").name == "loop"
    assert get_engine("vmap").name == "vmap"
    eng = LoopEngine()
    assert get_engine(eng) is eng
    with pytest.raises(ValueError):
        get_engine("warp")


class _RawCountsEngine:
    """Stub engine that contributes a FIXED (possibly wrong-layout)
    vote-count array, for exercising the aggregate's layout contract
    without building per-token learners."""
    name = "raw"

    def __init__(self, counts):
        self.counts = np.asarray(counts, dtype=np.int32)

    def student_vote_counts(self, learner, states, X, num_classes, *,
                            consistent=True):
        return self.counts


def _stub_update(pid, kind=None):
    return PartyUpdate(party_id=pid, student_states=[None],
                       vote_gaps=np.zeros(4, np.float32),
                       num_examples=8, learner_kind=kind,
                       meta={"num_query_labels": 0, "encoded_bytes": 0})


def _stub_binding(counts):
    return ResolvedBinding(learner=None, student_learner=None,
                           engine=_RawCountsEngine(counts))


def _agg(bindings=None):
    cfg = FedKTConfig(num_parties=2, num_partitions=1, num_subsets=1,
                      num_classes=2, privacy_level="L0", seed=0)
    return StreamingVoteAggregate(cfg, None, _RawCountsEngine(
        np.zeros((8, 2))), np.zeros((8, 14), np.float32),
        bindings=bindings)


def test_aggregate_rejects_vote_unit_mismatch():
    """The footgun this PR closes: a party voting 2 units/query (the
    per-token layout) folded against a 1-unit/query round used to
    broadcast or crash deep in jnp; now it is refused with an error
    naming BOTH parties and their unit counts."""
    agg = _agg(bindings={0: _stub_binding(np.zeros((8, 2))),
                         1: _stub_binding(np.zeros((16, 2)))})
    agg.add(_stub_update(0))
    with pytest.raises(ValueError, match=r"(?s)party 1.*2 unit\(s\)/"
                                         r"query.*party 0.*1 unit\(s\)"
                                         r"/query.*per-token"):
        agg.add(_stub_update(1))
    # the refused update was NOT folded
    assert agg.num_parties == 1 and agg.party_ids == [0]


def test_aggregate_rejects_class_count_mismatch():
    agg = _agg(bindings={0: _stub_binding(np.zeros((8, 3)))})
    with pytest.raises(ValueError, match=r"party 0.*num_classes=2"):
        agg.add(_stub_update(0))


def test_aggregate_rejects_declared_kind_mismatch():
    """A decoded update whose wire-declared learner kind contradicts
    the session's binding for that party must be refused before its
    states are run under the wrong model."""
    agg = _agg(bindings={0: _stub_binding(np.zeros((8, 2)))})
    with pytest.raises(ValueError, match="declares learner kind 'rf'"):
        agg.add(_stub_update(0, kind="rf"))
    # undeclared (None) skips the cross-check — pre-binding updates
    # still fold
    agg.add(_stub_update(0))
    assert agg.num_parties == 1


def test_aggregate_still_rejects_duplicates():
    agg = _agg()
    agg.add(_stub_update(0))
    with pytest.raises(ValueError, match="duplicate update from party 0"):
        agg.add(_stub_update(0))


def test_message_wire_sizes():
    tree = {"w": np.zeros((4, 8), np.float32), "b": np.zeros(8, np.int32)}
    assert pytree_bytes(tree) == 4 * 8 * 4 + 8 * 4
    assert pytree_bytes(jax.eval_shape(lambda: tree)) == pytree_bytes(tree)
    assert label_wire_bytes(750) == 3000
