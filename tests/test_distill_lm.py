"""LM-scale FedKT machinery: stacked-teacher label step + distillation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import lm_batch, smoke_model
from repro.configs import TrainConfig
from repro.core.distill import (make_decode_step, make_label_step,
                                make_prefill_step, make_train_step)


def test_label_step_votes_match_individual_predicts():
    cfg, model = smoke_model("stablelm-3b")
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    members = [model.init(k) for k in keys]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *members)
    batch = {"tokens": lm_batch(cfg, 2, 16)["tokens"]}
    label_step = jax.jit(make_label_step(model, 3))
    labels, gap = label_step(stacked, batch)
    # oracle: per-member predict + majority
    preds = np.stack([np.asarray(model.predict(m, batch))
                      for m in members])          # (3, 2, 16)
    from repro.kernels import ref
    exp, _ = ref.vote_aggregate_ref(
        jnp.asarray(preds.reshape(3, -1)), cfg.vocab_size)
    np.testing.assert_array_equal(np.asarray(labels).reshape(-1),
                                  np.asarray(exp))
    assert gap.shape == (2, 16) and (np.asarray(gap) >= 0).all()


@pytest.mark.slow
def test_distillation_learns_teacher_labels():
    """A student trained on voted labels fits them (distillation works)."""
    cfg, model = smoke_model("phi4-mini-3.8b", vocab_size=64)
    tokens = lm_batch(cfg, 8, 32)["tokens"]
    labels = jnp.asarray((np.asarray(tokens) * 7 + 1) % 64, jnp.int32)
    tcfg = TrainConfig(batch_size=8, seq_len=32, steps=150,
                       learning_rate=3e-3)
    step, opt = make_train_step(model, tcfg)
    step = jax.jit(step)
    params = model.init(jax.random.PRNGKey(1))
    opt_state = opt.init(params)
    batch = {"tokens": tokens, "labels": labels}
    losses = []
    for _ in range(150):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.3, (losses[0], losses[-1])
    # student now reproduces most voted labels
    preds = np.asarray(model.predict(params, batch))
    assert (preds == np.asarray(labels)).mean() > 0.8


def test_prefill_then_decode_greedy_continuation():
    cfg, model = smoke_model("granite-20b", dtype="float32",
                             param_dtype="float32")
    params = model.init(jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))
    B, P = 2, 12
    toks = lm_batch(cfg, B, P)["tokens"]
    logits, cache = prefill(params, {"tokens": toks})
    cache = jax.tree.map(
        lambda x: jnp.pad(x, [(0, 0), (0, 4)] + [(0, 0)] * (x.ndim - 2))
        if x.ndim >= 3 and x.shape[1] == P else x, cache)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for i in range(3):
        tok, cache = decode(params, tok, cache, jnp.int32(P + i))
        assert tok.shape == (B, 1)
