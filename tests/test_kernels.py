"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


ATT_SHAPES = [
    # B, Sq, Skv, H, KV, dh
    (1, 128, 128, 4, 4, 64),
    (2, 256, 256, 4, 2, 64),     # GQA
    (2, 256, 256, 8, 1, 128),    # MQA
    (1, 384, 384, 2, 2, 128),    # non-pow2 seq (pad path)
]


@pytest.mark.parametrize("shape", ATT_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (96, 0.0), (0, 30.0)])
def test_flash_attention_vs_ref(shape, dtype, window, softcap):
    B, Sq, Skv, H, KV, dh = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, Sq, H, dh), dtype)
    k = _rand(ks[1], (B, Skv, KV, dh), dtype)
    v = _rand(ks[2], (B, Skv, KV, dh), dtype)
    out_ref = ref.attention_ref(q, k, v, causal=True, window=window,
                                softcap=softcap)
    out_k = ops.attention(q, k, v, causal=True, window=window,
                          softcap=softcap, impl="kernel_interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attention_xla_chunked_vs_ref(dtype):
    B, S, H, KV, dh = 2, 320, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, S, H, dh), dtype)
    k = _rand(ks[1], (B, S, KV, dh), dtype)
    v = _rand(ks[2], (B, S, KV, dh), dtype)
    out_ref = ref.attention_ref(q, k, v, causal=True, window=128)
    out_x = ops.attention(q, k, v, causal=True, window=128, impl="xla",
                          block_q=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_x, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=tol, rtol=tol)


def test_attention_decode_offset():
    """Decode (Sq=1, q_offset) equals the last row of full attention."""
    B, S, H, KV, dh = 2, 96, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (B, S, H, dh), jnp.float32)
    k = _rand(ks[1], (B, S, KV, dh), jnp.float32)
    v = _rand(ks[2], (B, S, KV, dh), jnp.float32)
    full = ref.attention_ref(q, k, v, causal=True)
    one = ops.attention(q[:, -1:], k, v, causal=True, q_offset=S - 1,
                        impl="xla")
    np.testing.assert_allclose(np.asarray(one[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-5)


@pytest.mark.parametrize("B,S,D", [(1, 256, 256), (2, 512, 256),
                                   (2, 128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_vs_ref(B, S, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = _rand(ks[0], (B, S, D), dtype)
    log_a = -jnp.abs(_rand(ks[1], (B, S, D), dtype)) * 0.1
    h0 = _rand(ks[2], (B, D), jnp.float32)
    h_ref, hl_ref = ref.rglru_scan_ref(x, log_a, h0)
    for impl in ("kernel_interpret", "xla"):
        h, hl = ops.rglru(x, log_a, h0, impl=impl)
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(np.asarray(h, np.float32),
                                   np.asarray(h_ref, np.float32),
                                   atol=tol, rtol=tol)
        np.testing.assert_allclose(np.asarray(hl, np.float32),
                                   np.asarray(hl_ref, np.float32),
                                   atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,H,dh", [(1, 256, 2, 64), (2, 128, 4, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_vs_ref(B, S, H, dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    r = _rand(ks[0], (B, S, H, dh), dtype) * 0.5
    k = _rand(ks[1], (B, S, H, dh), dtype) * 0.5
    v = _rand(ks[2], (B, S, H, dh), dtype) * 0.5
    w = jax.nn.sigmoid(_rand(ks[3], (B, S, H, dh), jnp.float32)
                       ).astype(dtype)
    u = _rand(ks[4], (H, dh), jnp.float32) * 0.1
    s0 = _rand(ks[5], (B, H, dh, dh), jnp.float32) * 0.1
    o_ref, s_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    o_k, s_k = ops.wkv(r, k, v, w, u, s0, impl="kernel_interpret")
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               atol=tol, rtol=tol)


def test_wkv_decode_step_matches_scan():
    B, H, dh = 2, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    args = [_rand(k, (B, 3, H, dh), jnp.float32) * 0.4 for k in ks[:3]]
    w = jax.nn.sigmoid(_rand(ks[3], (B, 3, H, dh), jnp.float32))
    u = _rand(ks[4], (H, dh), jnp.float32) * 0.1
    o_ref, s_ref = ref.wkv6_ref(*args, w, u)
    s = None
    outs = []
    for t in range(3):
        o, s = ops.wkv(args[0][:, t:t+1], args[1][:, t:t+1],
                       args[2][:, t:t+1], w[:, t:t+1], u, s, impl="xla")
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(o_ref), atol=1e-5)


@pytest.mark.parametrize("M,T,U", [(5, 128, 512), (16, 256, 1024),
                                   (3, 64, 10)])
def test_vote_aggregate_vs_ref(M, T, U):
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    preds = jax.random.randint(ks[0], (M, T), 0, U)
    noise = jax.random.laplace(ks[1], (T, U)) * 0.3
    labels_ref, counts = ref.vote_aggregate_ref(preds, U, noise)
    clean_srt = np.sort(np.asarray(counts), axis=1)
    for impl in ("kernel_interpret", "xla"):
        labels, top1, top2 = ops.votes(preds, U, noise, impl=impl)
        np.testing.assert_array_equal(np.asarray(labels),
                                      np.asarray(labels_ref))
        # top1 must be the noisy score of the winning class
        scores = np.asarray(counts, np.float32) + np.asarray(noise)
        np.testing.assert_allclose(np.asarray(top1),
                                   scores.max(axis=1), atol=1e-4)
        # one-histogram variant: same noisy labels + CLEAN top-2 (the
        # kernel's _block_top2/_fold_top2 accumulation across class
        # blocks — the Lemma-7 gap input)
        lc, cc, c1, c2 = ops.votes_with_clean(preds, U, noise, impl=impl)
        np.testing.assert_array_equal(np.asarray(lc),
                                      np.asarray(labels_ref))
        np.testing.assert_allclose(np.asarray(c1), clean_srt[:, -1])
        np.testing.assert_allclose(np.asarray(c2), clean_srt[:, -2])
        if impl == "xla":
            np.testing.assert_array_equal(np.asarray(cc),
                                          np.asarray(counts))
        else:
            assert cc is None


def test_vote_top2_gap_clean():
    """Without noise, top1/top2 are the two largest vote counts."""
    preds = jnp.array([[0, 1, 2], [0, 1, 0], [0, 2, 2], [1, 1, 2]])  # (4,3)
    labels, top1, top2 = ops.votes(preds, 4, None, impl="xla")
    counts = np.asarray(ref.vote_aggregate_ref(preds, 4)[1])
    srt = np.sort(counts, axis=1)
    np.testing.assert_allclose(np.asarray(top1), srt[:, -1])
    np.testing.assert_allclose(np.asarray(top2), srt[:, -2])


def _tree_hist_scatter(xb, node, w, num_nodes, num_bins):
    """The scatter-add formulation ops.tree_hist replaced (the old
    trees.py per-level build): one giant 1-D scatter over an (N, F)
    broadcast of each weight channel.  Kept here as a second oracle."""
    N, F = xb.shape
    flat = (node[:, None] * F + jnp.arange(F)[None]) * num_bins + xb

    def one_channel(wk):
        h = jnp.zeros((num_nodes * F * num_bins,), jnp.float32)
        h = h.at[flat.reshape(-1)].add(
            jnp.broadcast_to(wk[:, None], (N, F)).reshape(-1))
        return h.reshape(num_nodes, F, num_bins)

    return jnp.stack([one_channel(w[k]) for k in range(w.shape[0])])


@pytest.mark.parametrize("N,F,n,K", [(300, 14, 8, 2), (128, 6, 1, 2),
                                     (512, 33, 16, 3), (70, 5, 32, 1)])
def test_tree_hist_vs_ref_and_scatter(N, F, n, K):
    """ops.tree_hist (interpret-mode Pallas AND restructured xla) vs the
    naive einsum oracle vs the legacy scatter-add formulation, on random
    float weights — including zero-weight rows (the padding invariant:
    w == 0 rows must contribute EXACT zeros, bit-identical)."""
    B = 32
    rng = np.random.default_rng(N + F + n + K)
    xb = jnp.asarray(rng.integers(0, B, (N, F)), jnp.int32)
    node = jnp.asarray(rng.integers(0, n, (N,)), jnp.int32)
    w = jnp.asarray(rng.random((K, N)), jnp.float32)
    w = w.at[:, -N // 4:].set(0.0)              # padding-style zero rows

    h_ref = ref.tree_hist_ref(xb, node, w, n, B)
    h_sct = _tree_hist_scatter(xb, node, w, n, B)
    np.testing.assert_allclose(np.asarray(h_sct), np.asarray(h_ref),
                               atol=1e-4, rtol=1e-5)
    for impl in ("kernel_interpret", "xla"):
        h = ops.tree_hist(xb, node, w, num_nodes=n, num_bins=B, impl=impl)
        assert h.shape == (K, n, F, B)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                                   atol=1e-4, rtol=1e-5)
        # w == 0 rows contribute EXACT zeros: scrambling their xb/node
        # (what padding rows hold is arbitrary) is bit-identical
        pad = N // 4
        xb2 = xb.at[-pad:].set(
            jnp.asarray(rng.integers(0, B, (pad, F)), jnp.int32))
        node2 = node.at[-pad:].set(
            jnp.asarray(rng.integers(0, n, (pad,)), jnp.int32))
        h2 = ops.tree_hist(xb2, node2, w, num_nodes=n, num_bins=B,
                           impl=impl)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(h2))


def test_tree_hist_stacked_teacher_axis():
    """vmap over the teacher axis (the stacked-fit usage): every
    teacher's histogram equals its own unbatched build, for both the
    interpret-mode kernel and the xla path."""
    k, N, F, n, B = 3, 160, 7, 4, 32
    rng = np.random.default_rng(0)
    xb = jnp.asarray(rng.integers(0, B, (N, F)), jnp.int32)
    nodes = jnp.asarray(rng.integers(0, n, (k, N)), jnp.int32)
    ws = jnp.asarray(rng.random((k, 2, N)), jnp.float32)
    for impl in ("kernel_interpret", "xla"):
        hv = jax.vmap(lambda nd, wk: ops.tree_hist(
            xb, nd, wk, num_nodes=n, num_bins=B, impl=impl))(nodes, ws)
        for i in range(k):
            one = ops.tree_hist(xb, nodes[i], ws[i], num_nodes=n,
                                num_bins=B, impl=impl)
            np.testing.assert_array_equal(np.asarray(hv[i]),
                                          np.asarray(one))


def test_node_hist_leaf_build():
    """ops.node_hist (the leaf build) == direct one-hot contraction."""
    N, L, K = 200, 16, 2
    rng = np.random.default_rng(1)
    node = jnp.asarray(rng.integers(0, L, (N,)), jnp.int32)
    w = jnp.asarray(rng.random((K, N)), jnp.float32)
    expect = jnp.einsum("ki,il->kl", w,
                        jax.nn.one_hot(node, L, dtype=jnp.float32))
    for impl in ("kernel_interpret", "xla"):
        got = ops.node_hist(node, w, num_nodes=L, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                                   atol=1e-4, rtol=1e-5)


# imported here, below the deterministic cases, so a missing
# hypothesis skips ONLY the property tests that follow
from hypothesis_compat import given, settings, st  # noqa: E402


@given(st.integers(1, 24), st.integers(1, 40), st.integers(2, 100),
       st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None)
def test_votes_sort_property(M, T, U, seed):
    """Sort-mode voting (LM-scale path) == histogram oracle for any
    (M, T, U), including label, top-1 and top-2 counts."""
    rng = np.random.default_rng(seed)
    preds = jnp.asarray(rng.integers(0, U, (M, T)), jnp.int32)
    l_ref, counts = ref.vote_aggregate_ref(preds, U)
    labels, top1, top2 = ops.votes_sort(preds)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(l_ref))
    srt = np.sort(np.asarray(counts), axis=1)
    np.testing.assert_allclose(np.asarray(top1), srt[:, -1])
    if U >= 2:
        np.testing.assert_allclose(np.asarray(top2), srt[:, -2])
