"""Optimizer substrate."""
import jax
import jax.numpy as jnp

from repro.optim import (adamw, clip_by_global_norm, prox_grads, sgd,
                         warmup_cosine)


def _quad_min(opt, steps=200, lr=0.1):
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, lr)
    return float(loss(params))


def test_adamw_minimizes_quadratic():
    assert _quad_min(adamw()) < 1e-3


def test_sgd_momentum_minimizes_quadratic():
    assert _quad_min(sgd(momentum=0.9), lr=0.05) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    total = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                               for x in jax.tree.leaves(clipped))))
    assert abs(total - 1.0) < 1e-4


def test_prox_grads_pull_toward_global():
    p = {"w": jnp.array(3.0)}
    gref = {"w": jnp.array(0.0)}
    g = {"w": jnp.array(0.0)}
    out = prox_grads(g, p, gref, mu=0.5)
    assert abs(float(out["w"]) - 1.5) < 1e-6


def test_warmup_cosine_shape():
    f = warmup_cosine(1.0, 10, 100)
    assert float(f(jnp.int32(0))) < 0.11
    assert abs(float(f(jnp.int32(10))) - 1.0) < 1e-5
    assert float(f(jnp.int32(100))) <= 0.11
