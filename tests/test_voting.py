"""Voting semantics + property-based invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.voting import consistent_vote, laplace, teacher_vote
from repro.kernels import ops, ref


def test_consistent_voting_paper_formula():
    """v_m(x) = s * |{i : v^i_m(x) = s}| — hand-checked example."""
    # 3 parties, s=2 students, 1 query
    # party 0: both say class 1 -> contributes 2 votes to class 1
    # party 1: split (1, 2)     -> ignored
    # party 2: both say class 0 -> contributes 2 votes to class 0
    preds = jnp.array([[[1], [1]], [[1], [2]], [[0], [0]]])
    vote = consistent_vote(preds, 3, consistent=True)
    np.testing.assert_array_equal(np.asarray(vote.counts[0]), [2, 2, 0])
    # without consistent voting: plain counts over all 6 students
    vote2 = consistent_vote(preds, 3, consistent=False)
    np.testing.assert_array_equal(np.asarray(vote2.counts[0]), [2, 3, 1])
    assert int(vote2.labels[0]) == 1


@given(st.integers(2, 6), st.integers(1, 3), st.integers(2, 5),
       st.integers(1, 12), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_consistent_vote_invariants(n, s, u, T, seed):
    rng = np.random.default_rng(seed)
    preds = jnp.asarray(rng.integers(0, u, (n, s, T)), jnp.int32)
    vote = consistent_vote(preds, u, consistent=True)
    counts = np.asarray(vote.counts)
    # counts are multiples of s, and at most n*s total
    assert (counts % s == 0).all()
    assert (counts.sum(axis=1) <= n * s).all()
    # labels in range
    assert (np.asarray(vote.labels) < u).all()
    # party permutation invariance
    perm = rng.permutation(n)
    vote_p = consistent_vote(preds[perm], u, consistent=True)
    np.testing.assert_array_equal(counts, np.asarray(vote_p.counts))


@given(st.integers(2, 6), st.integers(2, 3), st.integers(2, 5),
       st.integers(1, 8), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_party_level_sensitivity(n, s, u, T, seed):
    """Changing ONE party's students changes each count by <= s and the
    histogram by <= 2s in L1 — the paper's Theorem 1 sensitivity."""
    rng = np.random.default_rng(seed)
    preds = rng.integers(0, u, (n, s, T))
    preds2 = preds.copy()
    preds2[0] = rng.integers(0, u, (s, T))       # replace party 0 entirely
    c1 = np.asarray(consistent_vote(jnp.asarray(preds), u).counts)
    c2 = np.asarray(consistent_vote(jnp.asarray(preds2), u).counts)
    assert np.abs(c1 - c2).max() <= s
    assert np.abs(c1 - c2).sum(axis=1).max() <= 2 * s


@given(st.integers(2, 20), st.integers(2, 6), st.integers(1, 16),
       st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_teacher_vote_majority(t, u, T, seed):
    rng = np.random.default_rng(seed)
    preds = jnp.asarray(rng.integers(0, u, (t, T)), jnp.int32)
    vote = teacher_vote(preds, u)
    counts = np.asarray(ref.vote_aggregate_ref(preds, u)[1])
    labels = np.asarray(vote.labels)
    # winner has max count; counts total t
    assert (counts.sum(axis=1) == t).all()
    assert (counts[np.arange(T), labels] == counts.max(axis=1)).all()
    # gap consistent
    srt = np.sort(counts, axis=1)
    np.testing.assert_allclose(np.asarray(vote.top_gap),
                               srt[:, -1] - srt[:, -2])
    # clean histogram exposed on the xla path; None on the TPU kernel
    # path, which never materializes it (VoteResult contract)
    if vote.counts is not None:
        np.testing.assert_array_equal(np.asarray(vote.counts), counts)


def test_laplace_statistics():
    key = jax.random.PRNGKey(0)
    scale = 2.5
    x = np.asarray(laplace(key, (200_000,), scale))
    assert abs(x.mean()) < 0.05
    # Var(Laplace(0,b)) = 2 b^2
    assert abs(x.var() / (2 * scale ** 2) - 1) < 0.05


def test_laplace_symmetric_support_and_sign():
    """The uniform is clipped symmetrically, so both tails share one
    magnitude bound and the sign is unbiased (the old asymmetric clip
    truncated the negative tail short of the positive one)."""
    key = jax.random.PRNGKey(42)
    scale = 1.0
    x = np.asarray(laplace(key, (500_000,), scale))
    bound = -scale * np.log1p(-2.0 * (0.5 - 1e-7))
    assert x.max() <= bound + 1e-5
    assert -x.min() <= bound + 1e-5
    # sign balance: P(x > 0) = 1/2 (tolerance ~5 sigma at n=500k)
    assert abs(np.mean(x > 0) - 0.5) < 0.004
    # odd moments vanish; E|x| = scale for Laplace(0, scale)
    assert abs(x.mean()) < 0.01
    assert abs(np.mean(np.abs(x)) / scale - 1) < 0.01
    assert abs(np.mean(x ** 3)) < 0.2


def test_noise_flips_votes_at_high_gamma_scale():
    """Lap(1/gamma): tiny gamma (huge noise) must perturb labels;
    huge gamma (no noise) must reproduce clean labels."""
    rng = np.random.default_rng(0)
    preds = jnp.asarray(rng.integers(0, 4, (9, 256)), jnp.int32)
    clean = teacher_vote(preds, 4)
    noisy_hi = teacher_vote(preds, 4, gamma=1e6,
                            key=jax.random.PRNGKey(1))
    # tied queries flip arbitrarily under any noise; compare untied ones
    untied = np.asarray(clean.top_gap) > 0
    assert untied.sum() > 100
    np.testing.assert_array_equal(np.asarray(clean.labels)[untied],
                                  np.asarray(noisy_hi.labels)[untied])
    noisy_lo = teacher_vote(preds, 4, gamma=1e-3,
                            key=jax.random.PRNGKey(1))
    assert (np.asarray(noisy_lo.labels)
            != np.asarray(clean.labels)).mean() > 0.2


@given(st.integers(1, 64), st.integers(2, 300), st.integers(0, 2 ** 16))
@settings(max_examples=20, deadline=None)
def test_blocked_votes_property(M, U, seed):
    """Property: the blocked kernel path == ref for any (M, U)."""
    rng = np.random.default_rng(seed)
    T = 16
    preds = jnp.asarray(rng.integers(0, U, (M, T)), jnp.int32)
    labels_ref, _ = ref.vote_aggregate_ref(preds, U)
    labels, _, _ = ops.votes(preds, U, None, impl="xla")
    np.testing.assert_array_equal(np.asarray(labels),
                                  np.asarray(labels_ref))
