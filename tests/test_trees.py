"""JAX tree learners: correctness on separable data, GBDT improvement."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import trees as T
from repro.core.learners import GBDTLearner, RFLearner, accuracy


def _separable(n=600, f=6, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, f)).astype(np.float32)
    y = ((X[:, 0] > 0.2) ^ (X[:, 1] < -0.1)).astype(np.int32)
    return X, y


def test_single_tree_fits_axis_aligned():
    X, y = _separable()
    edges = jnp.asarray(T.make_bins(X))
    xb = T.binize(jnp.asarray(X), edges)
    tree = T.fit_tree_gini(xb, jnp.asarray(y), jnp.ones(len(y)),
                           jnp.ones(X.shape[1]), depth=4, num_classes=2)
    preds = jnp.argmax(T.tree_apply(tree, xb), -1)
    assert (np.asarray(preds) == y).mean() > 0.95


def test_random_forest_learner():
    X, y = _separable(seed=1)
    rf = RFLearner(num_classes=2, num_trees=8, depth=4)
    st = rf.fit(jax.random.PRNGKey(0), X[:400], y[:400])
    assert accuracy(rf, st, X[400:], y[400:]) > 0.9


def test_gbdt_improves_with_rounds():
    X, y = _separable(seed=2)
    accs = []
    for rounds in (2, 20):
        gb = GBDTLearner(num_rounds=rounds, depth=3)
        st = gb.fit(jax.random.PRNGKey(0), X[:400], y[:400])
        accs.append(accuracy(gb, st, X[400:], y[400:]))
    assert accs[1] >= accs[0]
    assert accs[1] > 0.9


def test_forest_feature_mask_respected():
    """Trees never split on masked features."""
    X, y = _separable()
    edges = jnp.asarray(T.make_bins(X))
    xb = T.binize(jnp.asarray(X), edges)
    mask = jnp.zeros(X.shape[1]).at[0].set(1.0)   # only feature 0 allowed
    tree = T.fit_tree_gini(xb, jnp.asarray(y), jnp.ones(len(y)), mask,
                           depth=3, num_classes=2)
    assert (np.asarray(tree[0]) == 0).all()
