"""JAX tree learners: correctness on separable data, GBDT improvement."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import trees as T
from repro.core.learners import GBDTLearner, RFLearner, accuracy


def _separable(n=600, f=6, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, f)).astype(np.float32)
    y = ((X[:, 0] > 0.2) ^ (X[:, 1] < -0.1)).astype(np.int32)
    return X, y


def test_single_tree_fits_axis_aligned():
    X, y = _separable()
    edges = jnp.asarray(T.make_bins(X))
    xb = T.binize(jnp.asarray(X), edges)
    tree = T.fit_tree_gini(xb, jnp.asarray(y), jnp.ones(len(y)),
                           jnp.ones(X.shape[1]), depth=4, num_classes=2)
    preds = jnp.argmax(T.tree_apply(tree, xb), -1)
    assert (np.asarray(preds) == y).mean() > 0.95


def test_random_forest_learner():
    X, y = _separable(seed=1)
    rf = RFLearner(num_classes=2, num_trees=8, depth=4)
    st = rf.fit(jax.random.PRNGKey(0), X[:400], y[:400])
    assert accuracy(rf, st, X[400:], y[400:]) > 0.9


def test_gbdt_improves_with_rounds():
    X, y = _separable(seed=2)
    accs = []
    for rounds in (2, 20):
        gb = GBDTLearner(num_rounds=rounds, depth=3)
        st = gb.fit(jax.random.PRNGKey(0), X[:400], y[:400])
        accs.append(accuracy(gb, st, X[400:], y[400:]))
    assert accs[1] >= accs[0]
    assert accs[1] > 0.9


def test_stacked_tree_fits_bit_identical_to_serial():
    """Zero-weight padding into a shared pow2 bucket: stacked RF/GBDT
    states equal the serial loop EXACTLY, even when dataset sizes (and
    hence individual buckets) differ — histograms ignore w == 0 rows."""
    rng = np.random.default_rng(3)
    sizes = (40, 70, 130)                # pow2 buckets 64, 128, 256
    Xs = [rng.normal(0, 1, (n, 6)).astype(np.float32) for n in sizes]
    ys = [((X[:, 0] > 0).astype(np.int32) ^ (X[:, 1] < 0)).astype(np.int32)
          for X in Xs]
    keys = jax.random.split(jax.random.PRNGKey(5), len(sizes))
    Xq = rng.normal(0, 1, (33, 6)).astype(np.float32)

    for learner in (RFLearner(num_classes=2, num_trees=6, depth=4),
                    GBDTLearner(num_rounds=8, depth=3)):
        stacked = learner.fit_stacked(keys, Xs, ys)
        preds = np.asarray(learner.predict_stacked(stacked, Xq))
        for i in range(len(sizes)):
            serial = learner.fit(keys[i], Xs[i], ys[i])
            sliced = jax.tree.map(lambda leaf: leaf[i], stacked)
            for a, b in zip(jax.tree.leaves(serial),
                            jax.tree.leaves(sliced)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            row = np.asarray(learner.predict(sliced, Xq))
            np.testing.assert_array_equal(preds[i], row)


def test_binize_matches_broadcast_compare():
    """searchsorted binize == the old O(N*F*B) broadcast-compare
    sum(X >= edges), including ties ON edges and duplicate edges
    (constant features)."""
    rng = np.random.default_rng(7)
    X = rng.normal(0, 1, (257, 9)).astype(np.float32)
    X[:, -1] = 1.0                          # constant => duplicate edges
    edges = T.make_bins(X)
    # land some values exactly on edges to exercise the >= tie
    X[::5, 0] = edges[0, 3]
    X[1::7, 2] = edges[2, 30]
    Xj, ej = jnp.asarray(X), jnp.asarray(edges)
    old = jnp.sum(Xj[:, :, None] >= ej[None], axis=-1).astype(jnp.int32)
    new = T.binize(Xj, ej)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))
    assert np.asarray(new).min() >= 0
    assert np.asarray(new).max() < T.NUM_BINS


def test_tree_fit_bench_smoke():
    """Tier-1 guard: the tree-fit benchmark runs end-to-end on its tiny
    config (scatter-vs-tree_hist parity asserts run inside)."""
    from benchmarks.tree_fit_bench import bench
    rec = bench(tiny=True, write=False)
    assert rec["hist_levels"] and rec["fits"]
    for row in rec["hist_levels"].values():
        assert row["tree_hist_ms"] > 0 and row["scatter_ms"] > 0
    for row in rec["fits"].values():
        assert row["warm_ms"] > 0


def test_forest_feature_mask_respected():
    """Trees never split on masked features."""
    X, y = _separable()
    edges = jnp.asarray(T.make_bins(X))
    xb = T.binize(jnp.asarray(X), edges)
    mask = jnp.zeros(X.shape[1]).at[0].set(1.0)   # only feature 0 allowed
    tree = T.fit_tree_gini(xb, jnp.asarray(y), jnp.ones(len(y)), mask,
                           depth=3, num_classes=2)
    assert (np.asarray(tree[0]) == 0).all()
