"""Crash safety under deterministic fault injection: the write-ahead
round journal, coordinator resume, idempotent (send-until-ACK) delivery,
and NAK reason codes — driven by the seeded chaos harness in
federation/faults.py.  The load-bearing claim throughout: a round that
is crashed, corrupted, or duplicated mid-flight finishes BIT-IDENTICAL
to the uninterrupted serial loop."""
import os
import socket
import struct
import time

import jax
import numpy as np
import pytest

from repro.configs.base import FedKTConfig
from repro.core.learners import GBDTLearner, NNLearner, RFLearner
from repro.data.synthetic import tabular_binary
from repro.federation import (ChaosProxy, Fault, FaultPlan, FedKTSession,
                              JournalExistsError, QuorumError,
                              RoundJournal, SocketTransport,
                              UpdateRefused)
from repro.federation.codec import encode_update
from repro.federation.engines import LoopEngine
from repro.federation.net import (ACK, NAK, NAK_CORRUPT, NAK_DUPLICATE,
                                  NAK_UNKNOWN_PARTY, Coordinator,
                                  send_update_frame)
from repro.federation.party import Party
from repro.models.smallnets import MLP


@pytest.fixture(scope="module")
def data():
    return tabular_binary(n=512, seed=0)


def make_nn():
    return NNLearner(MLP(14, 2, hidden=8), num_classes=2, steps=20)


@pytest.fixture(scope="module")
def learner():
    return make_nn()


CFG2 = dict(num_parties=2, num_partitions=1, num_subsets=2,
            num_classes=2, privacy_level="L2", gamma=0.1,
            query_fraction=0.5, seed=7)


def _tree_equal(a, b):
    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(leaves_a) == len(leaves_b)
    for la, lb in zip(leaves_a, leaves_b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_same_round(res, ref):
    assert res.accuracy == ref.accuracy
    assert res.epsilon == ref.epsilon
    _tree_equal(res.student_states, ref.student_states)
    assert res.meta["wire_bytes"] == ref.meta["wire_bytes"]


def _frame_for(data, learner, pid=0):
    """One real encoded PartyUpdate frame for raw-socket tests."""
    party = Party(party_id=pid, X=data["X_train"], y=data["y_train"],
                  indices=np.arange(96), cfg=FedKTConfig(**CFG2),
                  learner=learner, student_learner=learner)
    upd, _ = party.local_round(jax.random.PRNGKey(pid),
                               data["X_public"], 16, LoopEngine())
    return encode_update(upd)


def _raw_frame(port, payload):
    """Sends one raw frame; returns the full (1-2 byte) reply."""
    with socket.create_connection(("127.0.0.1", port), timeout=10) as s:
        s.sendall(struct.pack("<I", len(payload)) + payload)
        return s.recv(2)


# ---------------------------------------------------------------------------
# RoundJournal: durability format, replay, torn tails
# ---------------------------------------------------------------------------
def test_journal_roundtrip_and_resume_refusal(tmp_path):
    """Appended frames replay in order; a non-empty journal refuses to
    open without resume (never silently folds a stale round), and a
    journaled party refuses re-append (retransmits re-ACK instead)."""
    path = tmp_path / "round.jrnl"
    with RoundJournal(path) as j:
        j.append(0, b"frame-zero")
        j.append(2, b"frame-two")
        assert j.journaled_parties == [0, 2]
        with pytest.raises(ValueError, match="already journaled"):
            j.append(0, b"frame-zero")
    with pytest.raises(JournalExistsError, match="resume"):
        RoundJournal(path)
    with RoundJournal(path, resume=True) as j2:
        assert j2.resumed and dict(j2.records) == {0: b"frame-zero",
                                                   2: b"frame-two"}
        assert j2.corrupt_records_dropped == 0
        assert not j2.truncated_tail


def test_journal_truncates_torn_tail(tmp_path):
    """A record cut short by the crash (the fsync never finished) is
    truncated off the file, and the journal stays appendable — the
    interrupted party's retransmit lands on a clean prefix."""
    path = tmp_path / "round.jrnl"
    with RoundJournal(path) as j:
        j.append(0, b"frame-zero")
        j.append(1, b"frame-one")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 3)             # tear party 1's frame
    with RoundJournal(path, resume=True) as j2:
        assert j2.truncated_tail
        assert j2.journaled_parties == [0]
        assert os.path.getsize(path) < size - 3   # tail actually cut
        j2.append(1, b"frame-one")       # the retransmit re-journals
    with RoundJournal(path, resume=True) as j3:
        assert dict(j3.records) == {0: b"frame-zero", 1: b"frame-one"}
        assert not j3.truncated_tail


def test_journal_drops_corrupt_record_and_recovers(tmp_path):
    """A crc-failed record is skipped and counted; its party is NOT
    marked seen, so a fresh delivery re-journals it and a later resume
    replays the good copy."""
    path = tmp_path / "round.jrnl"
    with RoundJournal(path) as j:
        j.append(0, b"frame-zero")
        j.append(1, b"frame-one")
    raw = open(path, "rb").read()
    k = raw.index(b"frame-zero")
    with open(path, "wb") as f:          # flip one stored byte
        f.write(raw[:k] + b"X" + raw[k + 1:])
    with RoundJournal(path, resume=True) as j2:
        assert j2.corrupt_records_dropped == 1
        assert j2.journaled_parties == [1]
        j2.append(0, b"frame-zero")      # fresh delivery recovers
    with RoundJournal(path, resume=True) as j3:
        assert dict(j3.records) == {1: b"frame-one", 0: b"frame-zero"}
        assert j3.corrupt_records_dropped == 1   # stale record remains


def test_journal_frame_matches_is_byte_exact(tmp_path):
    """The re-ACK decision compares actual stored bytes, not just the
    (length, crc) digest — a crc collision can never smuggle a
    different update past the idempotency check."""
    path = tmp_path / "round.jrnl"
    with RoundJournal(path) as j:
        j.append(3, b"frame-three")
        assert j.frame_matches(3, b"frame-three")
        assert not j.frame_matches(3, b"frame-THREE")
        assert not j.frame_matches(4, b"frame-three")


# ---------------------------------------------------------------------------
# NAK reason codes and the retry loop (satellite: send_update_frame fix)
# ---------------------------------------------------------------------------
def test_fatal_nak_raises_immediately_with_reason(data, learner):
    """An unknown party is refused with reason ``unknown-party`` and
    the client raises UpdateRefused at once — no backoff is slept on a
    refusal retrying cannot fix (the old loop slept the full schedule
    before giving a reasonless error)."""
    coord = Coordinator([0, 1], port=0).start()
    try:
        frame = _frame_for(data, learner, pid=9)
        t0 = time.monotonic()
        with pytest.raises(UpdateRefused, match="unknown-party") as exc:
            send_update_frame("127.0.0.1", coord.port, frame,
                              retries=8, backoff_s=0.5)
        assert time.monotonic() - t0 < 2.0    # schedule would be >60s
        assert exc.value.reason == NAK_UNKNOWN_PARTY
        assert not exc.value.retryable
        assert "NAK" in str(exc.value)
    finally:
        coord.stop()


def test_corrupt_nak_is_retryable_on_the_wire(data, learner):
    """A frame damaged in flight is NAKed with reason ``corrupt`` —
    and the same bytes sent clean afterwards are ACKed: the refusal
    was about the transit, not the update."""
    coord = Coordinator([0], port=0).start()
    try:
        frame = _frame_for(data, learner, pid=0)
        bad = frame[:50] + bytes([frame[50] ^ 0xFF]) + frame[51:]
        assert _raw_frame(coord.port, bad) == NAK + bytes([NAK_CORRUPT])
        assert _raw_frame(coord.port, frame) == ACK
        assert coord.updates.get_nowait().party_id == 0
    finally:
        coord.stop()


def test_duplicate_with_different_bytes_is_fatal(data, learner):
    """Idempotency covers RETRANSMITS, not replacements: a second
    update from an already-folded party whose bytes differ is NAKed
    ``duplicate`` — accepting it would fork the round's history."""
    coord = Coordinator([0], port=0).start()
    try:
        party = Party(party_id=0, X=data["X_train"], y=data["y_train"],
                      indices=np.arange(96), cfg=FedKTConfig(**CFG2),
                      learner=learner, student_learner=learner)
        upd_a, _ = party.local_round(jax.random.PRNGKey(0),
                                     data["X_public"], 16, LoopEngine())
        upd_b, _ = party.local_round(jax.random.PRNGKey(1),
                                     data["X_public"], 16, LoopEngine())
        assert _raw_frame(coord.port, encode_update(upd_a)) == ACK
        assert _raw_frame(coord.port, encode_update(upd_b)) \
            == NAK + bytes([NAK_DUPLICATE])
        # the matching retransmit still re-ACKs afterwards
        assert _raw_frame(coord.port, encode_update(upd_a)) == ACK
        assert coord.re_acked == {0: 1}
    finally:
        coord.stop()


# ---------------------------------------------------------------------------
# Crash recovery: kill the coordinator mid-round, resume, bit-identity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make_learner", [
    make_nn,
    lambda: RFLearner(num_classes=2, num_trees=3, depth=2),
    lambda: GBDTLearner(num_rounds=3, depth=2),
], ids=["nn", "rf", "gbdt"])
def test_coordinator_killed_and_resumed_is_bit_identical(
        tmp_path, data, make_learner):
    """THE acceptance scenario: the coordinator dies in the worst
    window — party 0's frame journaled but never ACKed, never folded —
    and a restart with resume replays the journal, spawns only the
    missing party, and finishes the round bit-identical to the
    uninterrupted serial loop, for every learner kind."""
    journal = str(tmp_path / "round.jrnl")
    cfg = FedKTConfig(**CFG2)
    lrn = make_learner()
    ref = FedKTSession(lrn, data, cfg, engine="loop").run()

    plan = FaultPlan(kill_coordinator_on_party=0)
    crashed = SocketTransport(parallelism=1, journal_path=journal,
                              chaos_plan=plan, connect_retries=2,
                              backoff_s=0.01)
    with pytest.raises(QuorumError):
        FedKTSession(lrn, data, cfg, engine="loop",
                     transport=crashed).run()
    assert crashed.round_report["coordinator_killed"]
    assert any("kill_coordinator" in line for line in plan.log)
    # the crash window is covered: the frame IS durable despite no ACK
    with RoundJournal(journal, resume=True) as j:
        assert j.journaled_parties == [0]

    resumed = SocketTransport(parallelism=2, journal_path=journal,
                              resume=True)
    res = FedKTSession(lrn, data, cfg, engine="loop",
                       transport=resumed).run()
    _assert_same_round(res, ref)
    sock = res.meta["socket"]
    assert sock["resumed"] is True
    assert sock["replayed_parties"] == [0]
    assert sock["corrupt_records_dropped"] == 0
    assert sorted(sock["arrived"]) == [0, 1]


def test_fully_journaled_round_resumes_without_spawning(tmp_path, data,
                                                        learner):
    """A journal holding EVERY party replays to a complete round with
    no local rounds run at all — the restart-after-success case costs
    nothing but the replay."""
    journal = str(tmp_path / "round.jrnl")
    cfg = FedKTConfig(**CFG2)
    ref = FedKTSession(learner, data, cfg, engine="loop").run()
    first = FedKTSession(learner, data, cfg, engine="loop",
                         transport=SocketTransport(
                             parallelism=2, journal_path=journal)).run()
    _assert_same_round(first, ref)

    res = FedKTSession(learner, data, cfg, engine="loop",
                       transport=SocketTransport(
                           parallelism=2, journal_path=journal,
                           resume=True)).run()
    _assert_same_round(res, ref)
    sock = res.meta["socket"]
    assert sock["replayed_parties"] == [0, 1]
    assert sock["arrived"] and sorted(sock["arrived"]) == [0, 1]
    # no training happened: the party phase is pure replay
    assert res.meta["seconds"]["parties"] < \
        first.meta["seconds"]["parties"]


def test_journal_without_resume_refuses_stale_file(tmp_path, data,
                                                   learner):
    """Pointing a FRESH round at a journal that already holds records
    fails loudly before any party trains — resuming must be an explicit
    decision, not a default."""
    journal = str(tmp_path / "round.jrnl")
    with RoundJournal(journal) as j:
        j.append(0, b"stale-frame")
    cfg = FedKTConfig(**CFG2)
    with pytest.raises(JournalExistsError, match="resume"):
        FedKTSession(learner, data, cfg, engine="loop",
                     transport=SocketTransport(
                         parallelism=2, journal_path=journal)).run()


# ---------------------------------------------------------------------------
# Chaos proxy: scripted connection faults, end-to-end
# ---------------------------------------------------------------------------
def test_dropped_ack_retransmit_reacked_exactly_once(data, learner):
    """The lost-ACK drill: the proxy swallows party 0's ACK, the client
    retransmits identical bytes, the coordinator re-ACKs exactly once
    and never double-folds — the round is bit-identical regardless."""
    cfg = FedKTConfig(**CFG2)
    ref = FedKTSession(learner, data, cfg, engine="loop").run()
    plan = FaultPlan({0: Fault("drop_ack")})
    transport = SocketTransport(parallelism=1, chaos_plan=plan)
    res = FedKTSession(learner, data, cfg, engine="loop",
                       transport=transport).run()
    _assert_same_round(res, ref)
    sock = res.meta["socket"]
    assert sum(sock["re_acked"].values()) == 1
    assert any("drop_ack" in line for line in sock["chaos"])
    # exactly n updates folded: the retransmit never re-queued
    assert len(sock["arrived"]) == 2


def test_corrupted_frame_retried_through_proxy(data, learner):
    """In-flight corruption on the first delivery: the coordinator NAKs
    with reason ``corrupt``, the client treats it as retryable, and the
    clean retransmit completes a bit-identical round."""
    cfg = FedKTConfig(**CFG2)
    ref = FedKTSession(learner, data, cfg, engine="loop").run()
    plan = FaultPlan({0: Fault("corrupt", at_byte=64)})
    transport = SocketTransport(parallelism=1, chaos_plan=plan)
    res = FedKTSession(learner, data, cfg, engine="loop",
                       transport=transport).run()
    _assert_same_round(res, ref)
    sock = res.meta["socket"]
    assert any("corrupt" in e for e in sock["rejected"])
    assert any("corrupt byte" in line for line in sock["chaos"])


def test_killed_connection_retried_through_proxy(data, learner):
    """A connection killed mid-frame (partial bytes reach the
    coordinator) is survived by the client's send-until-ACK retry."""
    cfg = FedKTConfig(**CFG2)
    ref = FedKTSession(learner, data, cfg, engine="loop").run()
    plan = FaultPlan({0: Fault("kill_after", at_byte=100)})
    transport = SocketTransport(parallelism=1, chaos_plan=plan)
    res = FedKTSession(learner, data, cfg, engine="loop",
                       transport=transport).run()
    _assert_same_round(res, ref)
    assert any("kill_after" in line
               for line in res.meta["socket"]["chaos"])


def test_duplicate_delivery_never_double_folds(data, learner):
    """The proxy redelivers party 0's frame on a fresh connection after
    the real exchange: the coordinator re-ACKs it (idempotent) and the
    round folds each party exactly once."""
    cfg = FedKTConfig(**CFG2)
    ref = FedKTSession(learner, data, cfg, engine="loop").run()
    plan = FaultPlan({0: Fault("duplicate")})
    transport = SocketTransport(parallelism=1, chaos_plan=plan)
    res = FedKTSession(learner, data, cfg, engine="loop",
                       transport=transport).run()
    _assert_same_round(res, ref)
    sock = res.meta["socket"]
    assert len(sock["arrived"]) == 2
    assert sum(sock["re_acked"].values()) == 1


def test_seeded_two_party_chaos_smoke(tmp_path, data, learner):
    """Tier-1 chaos smoke (mirrored in CI): a seeded random fault plan
    over a journaled 2-party round — whatever the plan throws, the
    round must finish bit-identical to the serial loop.  Same seed,
    same faults, forever."""
    cfg = FedKTConfig(**CFG2)
    ref = FedKTSession(learner, data, cfg, engine="loop").run()
    plan = FaultPlan.random(seed=3, n_connections=6, fault_rate=0.6,
                            max_delay_s=0.05)
    assert plan.faults, "seed 3 must schedule at least one fault"
    transport = SocketTransport(
        parallelism=1, journal_path=str(tmp_path / "chaos.jrnl"),
        chaos_plan=plan)
    res = FedKTSession(learner, data, cfg, engine="loop",
                       transport=transport).run()
    _assert_same_round(res, ref)
    assert res.meta["socket"]["chaos"]   # something actually fired


def test_fault_plan_is_reproducible():
    """Chaos must replay: equal seeds give equal schedules, different
    seeds (eventually) differ, and unknown fault kinds fail loudly."""
    a = FaultPlan.random(seed=11, n_connections=32)
    b = FaultPlan.random(seed=11, n_connections=32)
    assert a.faults == b.faults
    assert any(FaultPlan.random(seed=s, n_connections=32).faults
               != a.faults for s in (12, 13, 14))
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("meteor-strike")


def test_chaos_proxy_passthrough_when_unfaulted(data, learner):
    """Ordinals with no scheduled fault relay untouched — the proxy in
    the path must be invisible to a clean round."""
    coord = Coordinator([0], port=0).start()
    plan = FaultPlan({})
    proxy = ChaosProxy("127.0.0.1", coord.port, plan).start()
    try:
        frame = _frame_for(data, learner, pid=0)
        assert _raw_frame(proxy.port, frame) == ACK
        assert coord.updates.get_nowait().party_id == 0
        assert proxy.connections == 1 and plan.log == []
    finally:
        proxy.stop()
        coord.stop()


# ---------------------------------------------------------------------------
# Fleet-scale soak (scheduled full run)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_fleet_32_party_chaos_soak(tmp_path, learner):
    """32 parties through the chaos proxy under a seeded fault barrage
    (corruption, killed connections, dropped ACKs, duplicates, delays)
    with the journal on: the constant-memory round still finishes
    bit-identical to the serial loop."""
    fleet_data = tabular_binary(n=4096, seed=1)
    cfg = FedKTConfig(num_parties=32, num_partitions=1, num_subsets=2,
                      num_classes=2, privacy_level="L2", gamma=0.1,
                      query_fraction=0.5, seed=11)
    rows = (len(fleet_data["X_train"]) // 32) * 32
    ix = np.array_split(np.arange(rows), 32)
    ref = FedKTSession(learner, fleet_data, cfg, engine="loop",
                       party_indices=ix).run()
    plan = FaultPlan.random(seed=5, n_connections=96, fault_rate=0.3)
    transport = SocketTransport(
        parallelism=8, journal_path=str(tmp_path / "soak.jrnl"),
        chaos_plan=plan)
    res = FedKTSession(learner, fleet_data, cfg, engine="loop",
                       party_indices=ix, retain_students=False,
                       transport=transport).run()
    assert res.accuracy == ref.accuracy
    assert res.epsilon == ref.epsilon
    assert res.student_states == []
    assert res.meta["wire_bytes"] == ref.meta["wire_bytes"]
    sock = res.meta["socket"]
    assert sorted(sock["arrived"]) == list(range(32))
    assert sock["chaos"], "the seeded barrage must actually fire"
    # every accepted frame is durable: the journal holds the round
    with RoundJournal(str(tmp_path / "soak.jrnl"), resume=True) as j:
        assert j.journaled_parties == list(range(32))
