"""Quickstart: one-shot federated learning (FedKT) in ~2 minutes on CPU.

Five silos hold heterogeneous shards of a tabular task.  A
``FedKTSession`` drives the paper's single communication round: each
``Party`` trains s x t teachers on disjoint subsets, distills s student
models from teacher votes on the public set, and sends ONE
``PartyUpdate``; the ``Server`` runs the consistent vote over all n*s
students and distills the final model.  Baselines (SOLO, centralized
PATE) are one-line ``Strategy`` objects against the same data and
partition.

The ``engine`` flag picks teacher execution: ``"loop"`` trains teachers
serially (the reference semantics), ``"vmap"`` trains each party's
whole teacher grid as one batched jit dispatch — same protocol, same
votes, a fraction of the dispatch overhead.

The ``transport`` flag picks WHERE the parties run and how their one
``PartyUpdate`` travels: ``"inprocess"`` (serial), ``"thread"`` /
``"subprocess"`` (parties fan out over ``parallelism`` workers; with
``"subprocess"`` each silo is its own interpreter and the update
crosses as serialized codec bytes).  Every transport is bit-identical
at a fixed seed, and the reported wire bytes are MEASURED encoded
sizes, not estimates.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.base import FedKTConfig
from repro.core.learners import NNLearner
from repro.data.synthetic import tabular_binary
from repro.federation import (CentralPATEStrategy, FedKTSession,
                              SoloStrategy)
from repro.models.smallnets import MLP

data = tabular_binary(n=6000, seed=0)
learner = NNLearner(MLP(num_features=14, num_classes=2, hidden=32),
                    num_classes=2, steps=200)

cfg = FedKTConfig(
    num_parties=5,        # n silos
    num_partitions=2,     # s student models per silo
    num_subsets=4,        # t teachers per partition
    num_classes=2,
    beta=0.5,             # Dirichlet heterogeneity
)

print("running FedKT (single communication round, vmap engine)...")
session = FedKTSession(learner, data, cfg, engine="vmap")
res = session.run(verbose=True)
solo = SoloStrategy(learner).run(data, cfg)
pate = CentralPATEStrategy(learner).run(data, cfg)

print(f"\nFedKT final-model accuracy : {res.accuracy:.3f}")
print(f"SOLO (no federation) mean  : {solo.accuracy:.3f}")
print(f"centralized PATE (upper bd): {pate.accuracy:.3f}")
wire = res.meta["wire_bytes"]
print(f"\ncommunication: n*M*(s+1) = {cfg.num_parties} models x "
      f"{cfg.num_partitions + 1} transfers — one round, "
      f"{wire['updates'] / 1024:.0f} KiB of student models up "
      f"(measured on the wire), "
      f"{wire['labels'] / 1024:.0f} KiB of labels down, done.")

# same round, parties fanned out in parallel — bit-identical result
print("\nre-running with parallel parties (thread transport)...")
par = FedKTSession(learner, data, cfg, engine="vmap",
                   transport="thread",
                   parallelism=cfg.num_parties).run()
assert par.accuracy == res.accuracy
print(f"parallel accuracy matches: {par.accuracy:.3f} "
      f"(parties took {par.meta['seconds']['parties']}s over "
      f"{par.meta['parallelism']} workers; "
      f"{par.meta['wire_bytes']['updates']} wire bytes measured)")
