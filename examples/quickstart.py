"""Quickstart: one-shot federated learning (FedKT) in ~2 minutes on CPU.

Five silos hold heterogeneous shards of a tabular task; one communication
round later the server has a model close to the centralized upper bound.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.base import FedKTConfig
from repro.core.fedkt import run_fedkt, run_pate_central, run_solo
from repro.core.learners import NNLearner
from repro.data.synthetic import tabular_binary
from repro.models.smallnets import MLP

data = tabular_binary(n=6000, seed=0)
learner = NNLearner(MLP(num_features=14, num_classes=2, hidden=32),
                    num_classes=2, steps=200)

cfg = FedKTConfig(
    num_parties=5,        # n silos
    num_partitions=2,     # s student models per silo
    num_subsets=4,        # t teachers per partition
    num_classes=2,
    beta=0.5,             # Dirichlet heterogeneity
)

print("running FedKT (single communication round)...")
res = run_fedkt(learner, data, cfg, verbose=True)
solo = run_solo(learner, data, cfg)
pate = run_pate_central(learner, data, cfg)

print(f"\nFedKT final-model accuracy : {res.accuracy:.3f}")
print(f"SOLO (no federation) mean  : {solo:.3f}")
print(f"centralized PATE (upper bd): {pate:.3f}")
print(f"\ncommunication: n*M*(s+1) = {cfg.num_parties} models x "
      f"{cfg.num_partitions + 1} transfers — one round, done.")
