"""End-to-end LM driver: FedKT at language-model scale.

Two parties each train transformer teachers on private token streams;
per-token ensemble voting labels a public stream (the blocked
vote_aggregate op — one collective round at datacenter scale); students
and then the server's final model are distilled from the votes.  Uses a
reduced phi4-family config so it runs on CPU; the same code path drives
the full configs through launch/train.py.

    PYTHONPATH=src python examples/fedkt_lm_distillation.py [--steps N]
"""
import argparse

import numpy as np

from repro.configs import FedKTConfig, TrainConfig, get_smoke
from repro.data import TokenDataset, synthetic
from repro.launch.train import eval_lm, fedkt_lm, train_lm
from repro.models import Model

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
args = ap.parse_args()

cfg = get_smoke("phi4-mini-3.8b").replace(vocab_size=512)
model = Model(cfg)
data = synthetic.tokens(n_seqs=192, seq_len=65, vocab=cfg.vocab_size)
tcfg = TrainConfig(batch_size=8, seq_len=64, steps=args.steps,
                   learning_rate=3e-3)

fcfg = FedKTConfig(num_parties=2, num_partitions=2, num_subsets=2,
                   num_classes=cfg.vocab_size)
out = fedkt_lm(model, data["train"], data["public"], fcfg, tcfg)

test = TokenDataset(data["test"])
final_loss = eval_lm(model, out["final_params"], test)

# baseline: train a single model on ONE party's data only (SOLO-ish)
solo = train_lm(model, TokenDataset(data["train"][:48]), tcfg,
                verbose=False)
solo_loss = eval_lm(model, solo["params"], test)
print(f"\nFedKT-distilled final model test loss: {final_loss:.4f}")
print(f"single-silo baseline test loss       : {solo_loss:.4f}")
