"""End-to-end LM driver: FedKT at language-model scale, on the session API.

Two parties each train transformer teachers on private token streams;
per-token ensemble voting labels a public stream (the fused label step —
one collective round at datacenter scale); students and then the
server's final model are distilled from the votes.  The whole round runs
through ``FedKTSession`` with the ``lm`` engine — the same driver,
transports, wire codec and accounting as the tabular learners — via the
``fedkt_lm`` wrapper.  Uses a reduced phi4-family config so it runs on
CPU; the same code path drives the full configs through launch/train.py.

    PYTHONPATH=src python examples/fedkt_lm_distillation.py [--steps N]
"""
import argparse

from repro.configs import FedKTConfig, TrainConfig, get_smoke
from repro.data import TokenDataset, synthetic
from repro.launch.train import eval_lm, fedkt_lm, train_lm
from repro.models import Model

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--engine", choices=["lm", "loop"], default="lm")
args = ap.parse_args()

cfg = get_smoke("phi4-mini-3.8b").replace(vocab_size=512)
model = Model(cfg)
data = synthetic.tokens(n_seqs=192, seq_len=65, vocab=cfg.vocab_size)
tcfg = TrainConfig(batch_size=8, seq_len=64, steps=args.steps,
                   learning_rate=3e-3)

fcfg = FedKTConfig(num_parties=2, num_partitions=2, num_subsets=2,
                   num_classes=cfg.vocab_size)
out = fedkt_lm(model, data["train"], data["public"], fcfg, tcfg,
               test=data["test"], engine=args.engine)

test = TokenDataset(data["test"])
final_loss = eval_lm(model, out["final_params"], test)

# baseline: train a single model on ONE party's data only (SOLO-ish)
solo = train_lm(model, TokenDataset(data["train"][:48]), tcfg,
                verbose=False)
solo_loss = eval_lm(model, solo["params"], test)
res = out["result"]
print(f"\nFedKT-distilled final model test loss: {final_loss:.4f}")
print(f"single-silo baseline test loss       : {solo_loss:.4f}")
print(f"next-token accuracy (session metric) : {res.accuracy:.4f}")
print(f"wire: {res.meta['wire_bytes']['updates']} update bytes "
      f"(measured framed), {res.meta['wire_bytes']['labels_framed']} "
      f"label bytes")
