"""Differentially-private FedKT: the (gamma, #queries) -> (epsilon, acc)
trade-off, with the data-dependent moments accountant (paper §4).

The session owns the accounting: under L1 the Server accounts over the
global vote histogram; under L2 each Party ships its vote-gap trace and
the parties compose in parallel (Thm 4).

    PYTHONPATH=src python examples/dp_privacy_sweep.py
"""
import numpy as np

from repro.configs.base import FedKTConfig
from repro.core import privacy as P
from repro.core.learners import NNLearner
from repro.data.synthetic import tabular_binary
from repro.federation import FedKTSession
from repro.models.smallnets import MLP

data = tabular_binary(n=6000, seed=0)
learner = NNLearner(MLP(14, 2, hidden=32), num_classes=2, steps=200)

print(f"{'level':6s} {'gamma':>6s} {'queries':>8s} {'eps':>8s} {'acc':>7s}")
for level in ("L1", "L2"):
    for gamma in (0.04, 0.1):
        for qf in (0.05, 0.2):
            cfg = FedKTConfig(num_parties=5, num_partitions=1,
                              num_subsets=5, num_classes=2,
                              privacy_level=level, gamma=gamma,
                              query_fraction=qf)
            res = FedKTSession(learner, data, cfg, engine="vmap").run()
            print(f"{level:6s} {gamma:6.2f} {qf:8.2f} "
                  f"{res.epsilon:8.2f} {res.accuracy:7.3f}")

# moments accountant vs advanced composition (paper §B.7)
gaps = np.full(90, 4.0)
ma = P.fedkt_l1_epsilon(gaps, 0.1, s=1, num_classes=2)
adv = P.advanced_composition(0.2, 90, 1e-5)
print(f"\n90 queries @ gamma=0.1: moments accountant eps={ma:.1f}  "
      f"advanced composition eps={adv:.1f}")
