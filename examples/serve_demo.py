"""Serving demo: batched prefill + greedy decode with KV caches across
architecture families (dense GQA, MoE, hybrid-recurrent, attention-free).

    PYTHONPATH=src python examples/serve_demo.py
"""
import jax
import numpy as np

from repro.configs import get_smoke
from repro.launch.serve import serve_batch
from repro.models import Model

rng = np.random.default_rng(0)
for arch in ("granite-20b", "mixtral-8x7b", "recurrentgemma-2b",
             "rwkv6-7b"):
    cfg = get_smoke(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
    print(f"--- {arch} ({'attention-free' if cfg.attention_free else 'attn'})")
    gen = serve_batch(model, params, prompts, gen=8)
    print("   tokens:", gen[0].tolist())
