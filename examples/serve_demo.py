"""End-to-end deployment demo: one federated round, then serve it.

The paper's one-shot protocol produces a distilled student; this demo
walks the whole deployment arc on synthetic data —

  1. TRAIN   — ``fedkt_lm`` runs a (tiny) FedKT session: party teacher
               ensembles vote per-token labels in one round, the final
               student distills on the public stream.
  2. PERSIST — the student checkpoint round-trips through
               ``repro.checkpoint`` (what a silo would actually ship).
  3. SERVE   — the restored params go behind the continuous-batching
               ``Engine``: staggered request arrivals, mixed prompt
               lengths, one persistent KV slot cache — and every
               stream is checked bit-identical to the serial
               ``serve_batch`` reference before the demo declares
               victory.

    PYTHONPATH=src python examples/serve_demo.py          # tiny, ~30s
    PYTHONPATH=src python examples/serve_demo.py --smoke  # smoke arch
"""
import argparse
import os
import tempfile


def main(tiny=True, ckpt_dir=None, verbose=True):
    import jax
    import numpy as np

    from repro import checkpoint as ckpt_lib
    from repro.configs import get_smoke
    from repro.configs.base import FedKTConfig, TrainConfig
    from repro.launch.train import fedkt_lm
    from repro.models import Model
    from repro.serving import Engine, serve_batch

    if tiny:
        from repro.configs.base import ModelConfig
        cfg = ModelConfig(name="tiny-lm", num_layers=1, d_model=32,
                          num_heads=2, num_kv_heads=2, d_ff=64,
                          vocab_size=64, dtype="float32",
                          param_dtype="float32")
        tcfg = TrainConfig(batch_size=4, seq_len=16, steps=2,
                           learning_rate=3e-3, warmup_steps=1)
        n_seqs, gen = 64, 8
    else:
        cfg = get_smoke("phi4-mini-3.8b").replace(
            dtype="float32", param_dtype="float32")
        tcfg = TrainConfig(batch_size=8, seq_len=32, steps=5,
                           learning_rate=3e-3, warmup_steps=1)
        n_seqs, gen = 128, 12
    model = Model(cfg)

    # 1. one federated round -> distilled student
    from repro.data import synthetic
    data = synthetic.tokens(n_seqs=n_seqs, seq_len=tcfg.seq_len + 1,
                            vocab=cfg.vocab_size, seed=0)
    fcfg = FedKTConfig(num_parties=2, num_partitions=2, num_subsets=2,
                       num_classes=cfg.vocab_size, beta=100.0, seed=0)
    out = fedkt_lm(model, data["train"], data["public"], fcfg, tcfg,
                   test=data["test"], verbose=verbose)

    # 2. checkpoint round-trip (what a silo ships to its serving tier)
    ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix="fedkt_student_")
    path = os.path.join(ckpt_dir, "student")
    ckpt_lib.save(path, out["final_params"])
    params = ckpt_lib.restore(path, model.init(jax.random.PRNGKey(0)))

    # 3. serve it: continuous batching over one persistent slot cache
    rng = np.random.default_rng(1)
    plens = [3, 5, 8, 12, 16]
    prompts = [np.asarray(data["test"][i, :p], np.int32)
               for i, p in enumerate(plens)]
    eng = Engine(model, params, num_slots=2, cache_len=64)
    eng.warmup(buckets=plens)
    eng.submit(prompts[0], gen)
    eng.submit(prompts[1], gen)
    eng.step()                               # arrivals mid-stream
    for p in prompts[2:]:
        eng.submit(p, gen)
    results = eng.run()

    # parity gate: each stream == its solo serial run, bit for bit
    parity = True
    for r in results:
        ref, _ = serve_batch(model, params, prompts[r.rid][None], gen,
                             verbose=False)
        if r.tokens != ref[0].tolist():
            parity = False
    if verbose:
        acc = out["result"].accuracy
        print(f"student next-token acc {acc:.4f}; served "
              f"{len(results)} streams, parity={parity}")
        for r in results:
            print(f"  req {r.rid} (plen {r.prompt_len:2d}) "
                  f"ttft {r.timing['ttft']*1e3:6.1f}ms "
                  f"-> {r.tokens}")
    return {"parity": parity, "results": results,
            "accuracy": out["result"].accuracy, "ckpt": path}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smoke arch instead of the tiny 1-layer LM")
    args = ap.parse_args()
    main(tiny=not args.smoke)
