"""Vertically-partitioned silos: three parties hold the SAME patients
but DIFFERENT feature columns, and federate in one FedKT round over
real TCP sockets.

Horizontal FedKT splits samples across silos; the vertical scenario
splits COLUMNS (a hospital holds labs, a bank holds transactions, a
telco holds usage — keyed by the same people).  The one-shot protocol
carries over unchanged because the cross-party contract is the vote
DOMAIN, not the features: every silo's students still emit one vote
per public query example, so three feature-masked silos fold into the
same (T, U) example-domain histogram a horizontal round uses.

The three moving parts:

  core.partition.vertical_split  — a seeded disjoint column cover plus
                                   the shared row order (every party
                                   aligns its rows by the common
                                   sample-id vector; row i must mean
                                   the same sample everywhere, because
                                   votes are summed per query row)
  feature_mask= on the learners  — each silo's models train and predict
                                   on ONLY its columns, so raw off-silo
                                   features never cross the boundary
  SocketTransport                — each party ships its one PartyUpdate
                                   over a real localhost TCP connection;
                                   the coordinator validates the
                                   declared vote domain at ACK time and
                                   folds each update as it lands

    PYTHONPATH=src python examples/vertical_fedkt.py
"""
import numpy as np

from repro.configs.base import FedKTConfig
from repro.core.learners import NNLearner, RFLearner
from repro.core.partition import vertical_split
from repro.data.synthetic import tabular_binary
from repro.federation import FedKTSession, PartyBinding, SocketTransport
from repro.models.smallnets import MLP

N_TRAIN, NUM_FEATURES, NUM_PARTIES = 4000, 14, 3

data = tabular_binary(n=N_TRAIN, seed=0)

# the shared join key: every silo stores its column slice keyed by the
# same sample ids (here the synthetic row ids); vertical_split returns
# the canonical row order all parties apply, plus one disjoint sorted
# column tuple per party
row_order, masks = vertical_split(np.arange(len(data["X_train"])),
                                  NUM_FEATURES, NUM_PARTIES, seed=0)
print("feature slices:", {f"party {i}": m for i, m in enumerate(masks)})

# each silo's learner is feature-masked — it never reads the other
# silos' columns; mixing model families still works (the vote domain,
# not the model, is the contract)
bindings = [
    PartyBinding(NNLearner(MLP(num_features=len(masks[0]), num_classes=2,
                               hidden=32), num_classes=2, steps=150,
                           feature_mask=masks[0])),
    PartyBinding(RFLearner(num_classes=2, num_trees=16, depth=5,
                           feature_mask=masks[1]), engine="vmap"),
    PartyBinding(NNLearner(MLP(num_features=len(masks[2]), num_classes=2,
                               hidden=32), num_classes=2, steps=150,
                           feature_mask=masks[2])),
]

cfg = FedKTConfig(num_parties=NUM_PARTIES, num_partitions=2,
                  num_subsets=3, num_classes=2, seed=0)

# every party holds ALL samples (same rows, different columns) — the
# vertical scenario's defining property
indices = [row_order.copy() for _ in range(NUM_PARTIES)]

# the server distills the final model on the full-width public queries
final = NNLearner(MLP(num_features=NUM_FEATURES, num_classes=2,
                      hidden=32), num_classes=2, steps=150)

print("running one 3-silo feature-split round over TCP...")
res = FedKTSession(bindings, data, cfg, final_learner=final,
                   party_indices=indices,
                   transport=SocketTransport(parallelism=3)).run(
                       verbose=True)

print(f"\nvertical ensemble final-model accuracy: {res.accuracy:.3f}")
for ident, row in res.by_domain.items():
    print(f"domain {ident}: parties {row['parties']}, "
          f"{len(row['labels'])} voted labels")
print("framed wire bytes by vote domain (measured codec frames): "
      + ", ".join(f"{k}={v}" for k, v in
                  sorted(res.meta["wire_bytes"]["by_domain"].items())))
print("per-party TCP frames:", res.meta["socket"]["framed_bytes"])
