"""Heterogeneous ensemble: a forest, a boosted-tree, and an MLP silo
in ONE FedKT round.

FedKT's model-agnosticism claim made concrete: the protocol never
inspects a model, only its votes — an integer (T, U) count histogram —
so silos with completely different model families federate through the
same session stack.  Each party declares a ``PartyBinding``: its own
teacher learner, student learner, and execution engine (the tree
parties ride the stacked vmap engine here while the nn party runs the
serial loop).  The server folds each arriving update under THAT
party's binding and the vote layout is the only cross-party contract.

The round result prices each model family separately: tree students
ship split/leaf tables, the MLP ships dense weights, and the reported
wire bytes are MEASURED codec frames, not estimates.

    PYTHONPATH=src python examples/heterogeneous_ensemble.py
"""
import numpy as np

from repro.configs.base import FedKTConfig
from repro.core.learners import (GBDTLearner, NNLearner, RFLearner,
                                 accuracy)
from repro.data.synthetic import tabular_binary
from repro.federation import FedKTSession, PartyBinding
from repro.models.smallnets import MLP

data = tabular_binary(n=6000, seed=0)

# three silos, three model families — each brings its own learner and
# its preferred engine (trees batch their fits under vmap; the MLP
# party stays on the serial loop)
bindings = [
    PartyBinding(RFLearner(num_classes=2, num_trees=20, depth=5),
                 engine="vmap"),
    PartyBinding(GBDTLearner(num_rounds=20, depth=4), engine="vmap"),
    PartyBinding(NNLearner(MLP(num_features=14, num_classes=2,
                               hidden=32), num_classes=2, steps=200)),
]

cfg = FedKTConfig(
    num_parties=3,        # one silo per model family above
    num_partitions=2,     # s student models per silo
    num_subsets=4,        # t teachers per partition
    num_classes=2,
    beta=0.5,             # Dirichlet heterogeneity
)

# the final model can be ANY of the families; distill into the MLP
final = NNLearner(MLP(num_features=14, num_classes=2, hidden=32),
                  num_classes=2, steps=200)

print("running one mixed rf + gbdt + nn FedKT round...")
res = FedKTSession(bindings, data, cfg, final_learner=final,
                   transport="thread").run(verbose=True)

print(f"\nensemble final-model accuracy: {res.accuracy:.3f} "
      f"(engine mix: {res.meta['engine']})")
print("\nper-party contribution:")
per_party = res.meta["wire_bytes"]["per_party"]
for pid, (binding, row) in enumerate(zip(bindings,
                                         res.meta["party_bindings"])):
    b = binding.resolve()
    student_acc = float(np.mean([
        accuracy(b.student_learner, state, data["X_test"],
                 data["y_test"])
        for state in res.student_states[pid]]))
    print(f"  party {pid}: {row['learner']:>4} students "
          f"(engine {row['engine']:>4}) — mean student accuracy "
          f"{student_acc:.3f}, {per_party[pid]:>6} wire bytes")

by_kind = res.meta["wire_bytes"]["by_learner_kind"]
print("\nwire bytes by model family (measured codec frames): "
      + ", ".join(f"{k}={v}" for k, v in sorted(by_kind.items())))
print("tree students ship split/leaf tables; the MLP ships dense "
      "weights — same protocol, one histogram.")
