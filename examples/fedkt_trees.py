"""Model-agnostic FedKT: federate a NON-differentiable model.

FedAvg cannot average decision trees; FedKT only needs fit/predict.
This example federates the pure-JAX GBDT across silos — the paper's
cod-rna experiment, on the synthetic stand-in task.  The tree learners
run on the batched vmap engine: each party's whole teacher grid (and
its students) trains as one stacked histogram fit, bit-identical to
the serial loop.

    PYTHONPATH=src python examples/fedkt_trees.py
"""
import jax

from repro.configs.base import FedKTConfig
from repro.core.learners import GBDTLearner, RFLearner, accuracy
from repro.data.synthetic import tabular_binary
from repro.federation import FedKTSession, SoloStrategy

data = tabular_binary(n=6000, seed=1)
cfg = FedKTConfig(num_parties=4, num_partitions=2, num_subsets=3,
                  num_classes=2)

for name, learner in [
    ("GBDT", GBDTLearner(num_rounds=15, depth=4)),
    ("RandomForest", RFLearner(num_classes=2, num_trees=10, depth=5)),
]:
    res = FedKTSession(learner, data, cfg, engine="vmap").run()
    solo = SoloStrategy(learner).run(data, cfg).accuracy
    st = learner.fit(jax.random.PRNGKey(0), data["X_train"],
                     data["y_train"])
    central = accuracy(learner, st, data["X_test"], data["y_test"])
    print(f"{name:13s} FedKT={res.accuracy:.3f}  SOLO={solo:.3f}  "
          f"central={central:.3f}")
