"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2:1 pattern.
[arXiv:2402.19427]

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
Pattern: (recurrent, recurrent, local-attn) tiled; 26 = 8*3 + 2 leaves a
two-recurrent-layer tail, matching Griffin's layout.
"""
from repro.configs.base import ATTN_LOCAL, RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    pattern=(RGLRU, RGLRU, ATTN_LOCAL),
    window=2048,
    mlp="gelu",
    norm="rmsnorm",
    rglru_conv_width=4,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="recurrentgemma-smoke", num_layers=3, d_model=256, num_heads=4,
    num_kv_heads=1, d_ff=512, vocab_size=512, window=64,
)
