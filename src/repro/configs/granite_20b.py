"""granite-20b [dense] — llama-arch code model, MQA (kv=1).
[arXiv:2405.04324]

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49_152,
    pattern=(ATTN,),
    mlp="gelu",
    norm="layernorm",
)

SMOKE = CONFIG.replace(
    name="granite-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=1, d_ff=512, vocab_size=512,
)
