"""Configuration system for the repro framework.

Everything is a frozen dataclass so configs hash, compare, and print cleanly
and can be used as static arguments to jit.  Architectures register
themselves in ``repro.configs.registry`` (one module per assigned arch) and
are selectable via ``--arch <id>`` in every launcher.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Block kinds (per-layer mixer type)
# ---------------------------------------------------------------------------
ATTN = "attn"          # softmax attention (GQA; window/softcap via fields)
ATTN_LOCAL = "attn_local"  # sliding-window attention
RGLRU = "rglru"        # RG-LRU recurrence (RecurrentGemma / Griffin)
RWKV = "rwkv"          # RWKV-6 time-mix recurrence


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN settings (GShard-style capacity routing)."""
    num_experts: int = 8
    top_k: int = 2
    num_shared_experts: int = 0      # deepseek-style always-on experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight
    first_k_dense: int = 0           # leading layers that use a dense FFN
    dense_ff_mult: int = 1           # d_ff multiplier for those dense layers


@dataclass(frozen=True)
class ModelConfig:
    """Unified transformer-family model configuration.

    One engine covers dense / MoE / hybrid-recurrent / attention-free /
    encoder-decoder architectures through the ``pattern`` field: a tuple of
    block kinds that is tiled across ``num_layers`` (remainder layers are
    applied unrolled after the scanned periods).
    """
    name: str = "model"
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # layer pattern, tiled over depth.  e.g. gemma2: (ATTN_LOCAL, ATTN);
    # recurrentgemma: (RGLRU, RGLRU, ATTN_LOCAL); rwkv6: (RWKV,)
    pattern: Tuple[str, ...] = (ATTN,)

    # attention details
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0            # stablelm uses partial rotary (0.25)
    window: int = 4096               # sliding window for ATTN_LOCAL blocks
    attn_softcap: float = 0.0        # gemma2 logit soft-capping (0 = off)
    final_softcap: float = 0.0       # gemma2 final-logit soft-capping
    qk_norm: bool = False

    # MLP / MoE
    mlp: str = "swiglu"              # "swiglu" | "gelu" | "relu2"
    moe: Optional[MoEConfig] = None

    # norms & residual structure
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm"
    post_norm: bool = False          # gemma2 post-block norms
    parallel_block: bool = False     # stablelm/gptj style attn+mlp in parallel
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500      # whisper: 30s of audio at 50 Hz

    # modality frontend stub: number of non-text embedding positions that
    # ``input_specs`` provides pre-computed (VLM patches / audio frames)
    frontend_embeds: int = 0

    # rwkv dims
    rwkv_head_dim: int = 64

    # recurrentgemma
    rglru_conv_width: int = 4
    rglru_c: float = 8.0             # gate sharpness constant

    # numerics
    dtype: str = "bfloat16"          # activation dtype
    param_dtype: str = "float32"

    # ----- derived -----
    @property
    def head_dim_(self) -> int:
        return (self.head_dim if self.head_dim
                else self.d_model // self.num_heads)

    @property
    def attention_free(self) -> bool:
        return all(k in (RGLRU, RWKV) for k in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """True if no block attends over unbounded context (long_500k ok)."""
        return all(k != ATTN for k in self.pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # number of scanned periods and unrolled tail layers
    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> Tuple[str, ...]:
        r = self.num_layers % len(self.pattern)
        return self.pattern[:r]


@dataclass(frozen=True)
class FedKTConfig:
    """FedKT algorithm hyper-parameters (paper notation)."""
    num_parties: int = 10            # n
    num_partitions: int = 2          # s
    num_subsets: int = 5             # t
    num_classes: int = 10            # u
    consistent_voting: bool = True
    privacy_level: str = "L0"        # "L0" | "L1" | "L2"
    gamma: float = 0.0               # Laplace scale is 1/gamma (0 = no noise)
    query_fraction: float = 1.0      # fraction of D_aux queried (DP budget)
    beta: float = 0.5                # Dirichlet concentration for partition
    seed: int = 0


@dataclass(frozen=True)
class TrainConfig:
    batch_size: int = 32
    seq_len: int = 128
    learning_rate: float = 1e-3
    weight_decay: float = 1e-6
    epochs: int = 10
    steps: int = 100
    optimizer: str = "adamw"
    warmup_steps: int = 10
    grad_clip: float = 1.0
    remat: bool = True
    microbatches: int = 1   # gradient-accumulation splits of the batch
    pregather: bool = True  # ZeRO-3 bf16 pre-gather (§Perf iter 1/7)
    seed: int = 0


@dataclass(frozen=True)
class MeshConfig:
    """Production mesh shape.  (pod, data, model) once multi_pod else
    (data, model)."""
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pods


# Input shapes assigned to this paper (see system spec) -----------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}
