"""phi4-mini-3.8b [dense] — RoPE, SwiGLU, GQA.  [arXiv:2412.08905]

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200_064,
    pattern=(ATTN,),
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

# Reduced same-family variant for CPU smoke tests.
SMOKE = CONFIG.replace(
    name="phi4-mini-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=4, d_ff=512, vocab_size=512,
)
