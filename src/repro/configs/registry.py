"""--arch <id> registry for the ten assigned architectures.

Each entry maps the public arch id (dashes, as assigned) to its config
module.  ``get_config(id)`` returns the full-scale ModelConfig;
``get_smoke(id)`` returns the reduced same-family variant used by CPU
smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_MODULES: Dict[str, str] = {
    "phi4-mini-3.8b":        "repro.configs.phi4_mini_3_8b",
    "mixtral-8x7b":          "repro.configs.mixtral_8x7b",
    "gemma2-27b":            "repro.configs.gemma2_27b",
    "recurrentgemma-2b":     "repro.configs.recurrentgemma_2b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "stablelm-3b":           "repro.configs.stablelm_3b",
    "deepseek-moe-16b":      "repro.configs.deepseek_moe_16b",
    "whisper-tiny":          "repro.configs.whisper_tiny",
    "rwkv6-7b":              "repro.configs.rwkv6_7b",
    "granite-20b":           "repro.configs.granite_20b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id]).SMOKE


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """Sub-quadratic variant used for the long_500k shape.

    Dense full-attention archs get the documented sliding-window variant
    (window 4096 on every attention block); natively sub-quadratic archs
    are returned unchanged.  See DESIGN.md §5.
    """
    from repro.configs.base import ATTN, ATTN_LOCAL
    if cfg.subquadratic:
        return cfg
    pattern = tuple(ATTN_LOCAL if k == ATTN else k for k in cfg.pattern)
    win = cfg.window if cfg.window else 4096
    return cfg.replace(pattern=pattern, window=min(win, 4096),
                       name=cfg.name + "-swa")
