"""llava-next-mistral-7b [vlm] — anyres tiling, mistral backbone.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.

The vision tower (CLIP-ViT) + projector are STUBBED per the assignment
carve-out: ``input_specs`` provides ``patch_embeds`` of shape
(batch, frontend_embeds, d_model) — pre-projected anyres patch embeddings
(2x2 tiles + base view of 576 patches each = 2880) that are concatenated
ahead of the token embeddings.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32_000,
    pattern=(ATTN,),
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    frontend_embeds=2880,   # anyres: 5 tiles x 576 patches, pre-projected
)

SMOKE = CONFIG.replace(
    name="llava-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=4, d_ff=512, vocab_size=512, frontend_embeds=16,
)
