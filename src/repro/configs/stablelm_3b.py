"""stablelm-3b [dense] — parallel block, partial rotary, LayerNorm.
[hf:stabilityai/stablelm-2-1_6b]

32L d_model=2560 32H (MHA kv=32) d_ff=6912 vocab=50304.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50_304,
    pattern=(ATTN,),
    mlp="swiglu",
    norm="layernorm",
    rope_pct=0.25,
    parallel_block=True,
)

SMOKE = CONFIG.replace(
    name="stablelm-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=8, d_ff=512, vocab_size=512,
)
