"""whisper-tiny [audio] — encoder-decoder, conv/mel frontend stubbed.
[arXiv:2212.04356]

4L (enc) + 4L (dec) d_model=384 6H (MHA kv=6) d_ff=1536 vocab=51865.

The mel-spectrogram + conv1d feature extractor is STUBBED per the
assignment carve-out: ``input_specs`` provides ``frame_embeds`` of shape
(batch, 1500, d_model) — the frames the conv frontend would produce for a
30 s window.  ``long_500k`` is SKIPPED for this arch (decoder max position
448 in the real model; a 500k decoder cache is architecturally
meaningless) — see DESIGN.md §5.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    num_layers=4,                # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    pattern=(ATTN,),
    mlp="gelu",
    norm="layernorm",
    is_encoder_decoder=True,
    num_encoder_layers=4,
    encoder_seq_len=1500,
    frontend_embeds=1500,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="whisper-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=4, d_ff=256, vocab_size=512, num_encoder_layers=2,
    encoder_seq_len=64, frontend_embeds=64,
)
