"""gemma2-27b [dense] — local+global alternating attention, logit softcap.
[arXiv:2408.00118]

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
"""
from repro.configs.base import ATTN, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256_000,
    pattern=(ATTN_LOCAL, ATTN),   # alternating local/global
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp="gelu",                   # gemma geglu ~ gated gelu; see layers.py
    norm="rmsnorm",
    post_norm=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="gemma2-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=4, head_dim=32, d_ff=512, vocab_size=512, window=64,
)
