"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts.
[arXiv:2401.06066]

28L d_model=2048 16H (MHA kv=16) d_ff=1408 (per expert) vocab=102400.
First layer uses a dense FFN (8x expert width ~ the paper's 10944).
"""
from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    pattern=(ATTN,),
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(
        num_experts=64, top_k=6, num_shared_experts=2,
        capacity_factor=1.25, first_k_dense=1, dense_ff_mult=8,
    ),
)

SMOKE = CONFIG.replace(
    name="deepseek-moe-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=8, d_ff=128, vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                  capacity_factor=1.5, first_k_dense=1, dense_ff_mult=4),
)
