from repro.configs.base import (  # noqa: F401
    ATTN, ATTN_LOCAL, RGLRU, RWKV,
    FedKTConfig, InputShape, INPUT_SHAPES, MeshConfig, ModelConfig,
    MoEConfig, TrainConfig,
)
from repro.configs.registry import (  # noqa: F401
    ARCH_IDS, get_config, get_smoke, long_context_variant,
)
