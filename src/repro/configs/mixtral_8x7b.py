"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2.
"""
from repro.configs.base import ATTN_LOCAL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32_000,
    pattern=(ATTN_LOCAL,),     # mistral-style SWA
    window=4096,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
)

SMOKE = CONFIG.replace(
    name="mixtral-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=4, d_ff=512, vocab_size=512, window=64,
    moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=1.5),
)
