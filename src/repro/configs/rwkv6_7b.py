"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892]

32L d_model=4096 d_ff=14336 vocab=65536.  64 WKV heads of dim 64.
"""
from repro.configs.base import RWKV, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    num_layers=32,
    d_model=4096,
    num_heads=64,             # wkv heads = d_model / rwkv_head_dim
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65_536,
    pattern=(RWKV,),
    mlp="relu2",              # rwkv channel-mix uses squared relu
    norm="layernorm",
    rwkv_head_dim=64,
)

SMOKE = CONFIG.replace(
    name="rwkv6-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=4, d_ff=512, vocab_size=512,
)
