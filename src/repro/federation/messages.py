"""Wire-level types of the FedKT protocol.

The one-shot protocol exchanges exactly one message kind per direction:

  PartyUpdate : party -> server, ONCE.  The party's s student states
                plus the clean vote-gap trace the L2 accountant needs.
                Never raw data, never teacher states — this is the
                paper's privacy boundary and its communication bound
                (n * s models on the wire, total).
  RoundResult : server -> caller.  Final model, accounting, metrics.

These stay plain dataclasses over pytrees; HOW a PartyUpdate crosses
the silo boundary is a transport concern (federation/transport.py) and
its byte form is the wire codec's (federation/codec.py) — every
transport serializes the update, so ``meta["encoded_bytes"]`` on a
received update is its measured wire size, and ``pytree_bytes`` here
remains the raw-array accounting the codec's payload matches exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

LABEL_BYTES = 4   # int32 vote labels — the server->party query answer unit


def pytree_bytes(tree: Any) -> int:
    """On-the-wire size of a state pytree (sum of array leaf bytes).
    Works on concrete arrays and on ShapeDtypeStructs (abstract lowering,
    launch/fedkt_dryrun.py)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += (int(np.prod(leaf.shape, dtype=np.int64))
                      * np.dtype(leaf.dtype).itemsize)
    return int(total)


def label_wire_bytes(num_queries: int) -> int:
    """Cost of shipping vote labels for ``num_queries`` public examples:
    O(T) integers — independent of vocab/class count and of model size."""
    return num_queries * LABEL_BYTES


@dataclass
class PartyUpdate:
    """Everything a party sends to the server in the single round."""
    party_id: int
    student_states: List[Any]          # s trained student pytrees
    vote_gaps: np.ndarray              # concat clean top-2 gaps (L2 acct)
    num_examples: int                  # local dataset size (for metrics)
    meta: Dict[str, Any] = field(default_factory=dict)

    def wire_bytes(self) -> int:
        """Payload bytes this update puts on the wire: the s student
        states PLUS the vote-gap trace — both ride in the same message
        (the server composes the parties' gap traces for the L2 bound
        and the trusted aggregator accounts under L1).  Matches the
        codec's measured payload exactly; the codec's framed size adds
        only the header (cross-checked in tests/test_transport.py)."""
        return pytree_bytes(self.student_states) + pytree_bytes(self.vote_gaps)


@dataclass
class RoundResult:
    """Outcome of one FedKT round, as produced by the session driver."""
    final_state: Any
    accuracy: float
    student_states: List[List[Any]]    # [party][partition] -> state
    epsilon: Optional[float] = None
    meta: Dict[str, Any] = field(default_factory=dict)
