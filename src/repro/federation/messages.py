"""Wire-level types of the FedKT protocol.

The one-shot protocol exchanges exactly one message kind per direction:

  PartyUpdate : party -> server, ONCE.  The party's s student states
                plus the clean vote-gap trace the L2 accountant needs.
                Never raw data, never teacher states — this is the
                paper's privacy boundary and its communication bound
                (n * s models on the wire, total).
  TokenLabels : the vote ANSWER as a message.  In the in-process modes
                labels never leave the silo, but at datacenter scale
                (launch/fedkt_dryrun.py) the ensemble members are
                sharded across hosts and the voted labels — one int32
                per query unit: per example for tabular learners, per
                TOKEN for the LM path — do cross the fabric, O(T)
                integers regardless of vocab or member count.  Framing
                it like every other message lets the dry-run price it
                with the codec's MEASURED framed bytes instead of a raw
                payload estimate.
  RoundResult : server -> caller.  Final model, accounting, metrics.

These stay plain dataclasses over pytrees; HOW a message crosses the
silo boundary is a transport concern (federation/transport.py) and its
byte form is the wire codec's (federation/codec.py) — every transport
serializes the update, so ``meta["encoded_bytes"]`` on a received
update is its measured wire size, and ``pytree_bytes`` here remains
the raw-array accounting the codec's payload matches exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

LABEL_BYTES = 4   # int32 vote labels — the server->party query answer unit


def pytree_bytes(tree: Any) -> int:
    """On-the-wire size of a state pytree (sum of array leaf bytes).
    Works on concrete arrays and on ShapeDtypeStructs (abstract lowering,
    launch/fedkt_dryrun.py)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += (int(np.prod(leaf.shape, dtype=np.int64))
                      * np.dtype(leaf.dtype).itemsize)
    return int(total)


def label_wire_bytes(num_queries: int) -> int:
    """Cost of shipping vote labels for ``num_queries`` public examples:
    O(T) integers — independent of vocab/class count and of model size."""
    return num_queries * LABEL_BYTES


@dataclass
class PartyUpdate:
    """Everything a party sends to the server in the single round.

    ``learner_kind`` names the STUDENT learner family the states belong
    to ("nn" | "rf" | "gbdt" | "lm" — bindings.learner_kind): in a
    heterogeneous session each party may bring a different model, so a
    decoded update must say which learner the server has to run to fold
    its votes.  The aggregate cross-checks it against the party's
    session binding and refuses a mismatch (federation/aggregate.py).
    None means "undeclared" (hand-built or pre-binding updates) and
    skips the check.

    ``domain`` is the party's declared VoteDomain (federation/domain.py)
    — the (unit, T, U, query-fingerprint) layout its student votes fold
    under.  It rides the codec header next to ``learner_kind``; the
    aggregate and the socket coordinator validate it against the domain
    the party's binding derives, and a mismatch is refused naming both
    domains.  None means "undeclared" (legacy frames, hand-built
    updates): the binding-derived domain applies unchecked.
    """
    party_id: int
    student_states: List[Any]          # s trained student pytrees
    vote_gaps: np.ndarray              # concat clean top-2 gaps (L2 acct)
    num_examples: int                  # local dataset size (for metrics)
    learner_kind: Optional[str] = None  # student-learner family name
    domain: Optional[Any] = None       # declared VoteDomain (or None)
    meta: Dict[str, Any] = field(default_factory=dict)

    def wire_bytes(self) -> int:
        """Payload bytes this update puts on the wire: the s student
        states PLUS the vote-gap trace — both ride in the same message
        (the server composes the parties' gap traces for the L2 bound
        and the trusted aggregator accounts under L1).  Matches the
        codec's measured payload exactly; the codec's framed size adds
        only the header (cross-checked in tests/test_transport.py)."""
        return pytree_bytes(self.student_states) + pytree_bytes(self.vote_gaps)


@dataclass
class TokenLabels:
    """One partition-ensemble's voted labels for the public queries.

    ``labels`` is int32, any shape — (T,) class labels for the tabular
    learners, (B, S) token labels on the LM path; the codec frames both
    identically (federation/codec.py encode_labels/decode_labels).
    Works with concrete arrays and with ShapeDtypeStructs, so the
    dry-run prices full-size label messages abstractly.
    """
    party_id: int
    labels: Any                        # int32 voted labels
    meta: Dict[str, Any] = field(default_factory=dict)

    def wire_bytes(self) -> int:
        """Raw label payload bytes; the codec's framed size adds only
        the header (cross-checked in tests/test_federation_lm.py)."""
        return pytree_bytes(self.labels)


@dataclass
class RoundResult:
    """Outcome of one FedKT round, as produced by the session driver.

    ``by_domain`` breaks the round down per vote domain (keyed by
    ``VoteDomain.ident``): each entry carries that domain's VoteResult
    (labels + counts + clean gap), its own epsilon fold, the parties
    that voted in it, and their student states.  A legacy single-domain
    round has exactly one entry, and the top-level fields
    (final_state/epsilon/student_states) are that entry's — the
    one-domain case of the fold.
    """
    final_state: Any
    accuracy: float
    student_states: List[List[Any]]    # [party][partition] -> state
    epsilon: Optional[float] = None
    meta: Dict[str, Any] = field(default_factory=dict)
    by_domain: Dict[str, Dict[str, Any]] = field(default_factory=dict)
