"""Federation API: FedKT's one-round protocol, decoupled from execution.

    Party / Server / FedKTSession  — the protocol (who sends what, once)
    bindings.PartyBinding           — what ONE party brings to a round:
                                      its learner, student learner, and
                                      engine.  A session takes a single
                                      learner (homogeneous shorthand —
                                      identical bindings for every
                                      party) or one binding per party
                                      (heterogeneous: rf + gbdt + nn in
                                      one ensemble; the integer (T, U)
                                      vote layout is the only
                                      cross-party contract)
    engines.LoopEngine / VmapEngine / LMEngine
                                    — how teachers train and vote
                                      (pluggable; "lm" is the sharded
                                      distill.py path — see
                                      docs/engines.md for the contract)
    codec                           — PartyUpdate / TokenLabels <->
                                      self-describing bytes (versioned
                                      frames: cross-host peers reject
                                      incompatible encodings loudly)
    transport.{InProcess,Thread,Subprocess}Transport
                                    — where parties run, how the ONE
                                      message crosses the silo boundary
                                      (always serialized via the codec)
    net.SocketTransport             — the fleet: updates over real TCP,
                                      streamed into the running vote
                                      aggregate, deadline/quorum
                                      straggler semantics, and crash
                                      recovery via the write-ahead
                                      journal (docs/federation.md)
    journal.RoundJournal            — fsync'd write-ahead log of
                                      accepted frames: a restarted
                                      coordinator replays it and waits
                                      only for the missing parties
    faults.FaultPlan / ChaosProxy   — seeded fault injection: scripted
                                      connection faults in an in-path
                                      TCP proxy, plus the coordinator
                                      kill window (tests/test_faults.py,
                                      launch/federate.py --chaos)
    aggregate.StreamingVoteAggregate— the server's running fold:
                                      constant memory in the party
                                      count, bit-identical to the batch
                                      vote in any arrival order; one
                                      histogram PER VOTE DOMAIN, so
                                      per-token and per-example voters
                                      coexist in a round
    domain.VoteDomain               — the typed (unit, T, U,
                                      query-fingerprint) vote layout:
                                      the one cross-party contract,
                                      declared per binding, validated on
                                      the wire and at fold time
                                      (docs/engines.md "Vote domains")
    strategies.*                    — every compared algorithm, one shape

See session.FedKTSession for the entry point; its ``transport=`` /
``parallelism=`` knobs fan independent parties out across threads,
worker processes, or TCP sockets with unchanged seeds.
"""
from repro.federation import codec  # noqa: F401
from repro.federation.aggregate import StreamingVoteAggregate  # noqa: F401
from repro.federation.bindings import (PartyBinding,  # noqa: F401
                                       ResolvedBinding, learner_kind,
                                       register_learner_kind)
from repro.federation.domain import (VoteDomain,  # noqa: F401
                                     example_domain, fingerprint_queries,
                                     learner_domain, token_domain)
from repro.federation.engines import (Engine, LMEngine,  # noqa: F401
                                      LoopEngine, VmapEngine, get_engine)
from repro.federation.messages import (PartyUpdate,  # noqa: F401
                                       RoundResult, TokenLabels,
                                       label_wire_bytes, pytree_bytes)
from repro.federation.faults import ChaosProxy, Fault, FaultPlan  # noqa: F401
from repro.federation.journal import (JournalError,  # noqa: F401
                                      JournalExistsError, RoundJournal)
from repro.federation.net import (Coordinator, QuorumError,  # noqa: F401
                                  SocketTransport, UpdateRefused,
                                  run_party_client)
from repro.federation.party import Party  # noqa: F401
from repro.federation.server import Server  # noqa: F401
from repro.federation.session import (FedKTSession,  # noqa: F401
                                      party_starting_keys, query_budget)
from repro.federation.strategies import (CentralPATEStrategy,  # noqa: F401
                                         FedKTStrategy, IterativeStrategy,
                                         SoloStrategy, Strategy,
                                         StrategyResult)
from repro.federation.transport import (InProcessTransport,  # noqa: F401
                                        SubprocessTransport,
                                        ThreadTransport, Transport,
                                        TransportBase, get_transport)
