"""Federation API: FedKT's one-round protocol, decoupled from execution.

    Party / Server / FedKTSession  — the protocol (who sends what, once)
    engines.LoopEngine / VmapEngine / LMEngine
                                    — how teachers train and vote
                                      (pluggable; "lm" is the sharded
                                      distill.py path — see
                                      docs/engines.md for the contract)
    codec                           — PartyUpdate / TokenLabels <->
                                      self-describing bytes
    transport.{InProcess,Thread,Subprocess}Transport
                                    — where parties run, how the ONE
                                      message crosses the silo boundary
                                      (always serialized via the codec)
    strategies.*                    — every compared algorithm, one shape

See session.FedKTSession for the entry point; its ``transport=`` /
``parallelism=`` knobs fan independent parties out across threads or
worker processes with unchanged seeds.
"""
from repro.federation import codec  # noqa: F401
from repro.federation.engines import (Engine, LMEngine,  # noqa: F401
                                      LoopEngine, VmapEngine, get_engine)
from repro.federation.messages import (PartyUpdate,  # noqa: F401
                                       RoundResult, TokenLabels,
                                       label_wire_bytes, pytree_bytes)
from repro.federation.party import Party  # noqa: F401
from repro.federation.server import Server  # noqa: F401
from repro.federation.session import FedKTSession, query_budget  # noqa: F401
from repro.federation.strategies import (CentralPATEStrategy,  # noqa: F401
                                         FedKTStrategy, IterativeStrategy,
                                         SoloStrategy, Strategy,
                                         StrategyResult)
from repro.federation.transport import (InProcessTransport,  # noqa: F401
                                        SubprocessTransport,
                                        ThreadTransport, Transport,
                                        get_transport)
