"""Strategies: every algorithm the paper compares is one Strategy.

A Strategy bundles its model/learner and hyper-parameters at
construction and exposes one call:

    strategy.run(data, cfg, party_indices=None) -> StrategyResult

so benchmarks and examples iterate over [FedKTStrategy(...),
SoloStrategy(...), IterativeStrategy(...)] instead of calling a zoo of
free functions with incompatible signatures.  All strategies keep the
exact PRNG seeding of the legacy free functions they replace, so
historical numbers reproduce.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedKTConfig
from repro.core.learners import accuracy
from repro.core.partition import dirichlet_partition
from repro.core.voting import teacher_vote
from repro.federation.session import FedKTSession


@dataclass
class StrategyResult:
    name: str
    accuracy: float
    epsilon: Optional[float] = None
    state: Any = None
    meta: Dict[str, Any] = field(default_factory=dict)


class Strategy(Protocol):
    name: str

    def run(self, data: Dict[str, np.ndarray], cfg: FedKTConfig, *,
            party_indices=None) -> StrategyResult:
        ...


@dataclass
class FedKTStrategy:
    """The paper's algorithm, via FedKTSession."""
    learner: Any
    engine: str = "loop"
    student_learner: Any = None
    final_learner: Any = None
    name: str = "fedkt"

    def run(self, data, cfg, *, party_indices=None) -> StrategyResult:
        session = FedKTSession(self.learner, data, cfg,
                               student_learner=self.student_learner,
                               final_learner=self.final_learner,
                               engine=self.engine,
                               party_indices=party_indices)
        res = session.run()
        return StrategyResult(self.name, res.accuracy, epsilon=res.epsilon,
                              state=res.final_state, meta=res.meta)


@dataclass
class SoloStrategy:
    """No federation: mean per-party local accuracy (paper Table 1)."""
    learner: Any
    name: str = "solo"

    def run(self, data, cfg, *, party_indices=None) -> StrategyResult:
        key = jax.random.PRNGKey(cfg.seed + 1)
        Xtr, ytr = data["X_train"], data["y_train"]
        if party_indices is None:
            party_indices = dirichlet_partition(ytr, cfg.num_parties,
                                                cfg.beta, cfg.seed)
        accs = []
        for ix in party_indices:
            key, kk = jax.random.split(key)
            st = self.learner.fit(kk, Xtr[ix], ytr[ix])
            accs.append(accuracy(self.learner, st, data["X_test"],
                                 data["y_test"]))
        return StrategyResult(self.name, float(np.mean(accs)),
                              meta={"per_party": accs})


@dataclass
class CentralPATEStrategy:
    """Centralized PATE upper bound (paper baseline 2): split the WHOLE
    training set into teachers, vote on D_aux, train one student.
    Ignores party_indices — centralization is the point."""
    learner: Any
    num_teachers: Optional[int] = None
    name: str = "pate-central"

    def run(self, data, cfg, *, party_indices=None) -> StrategyResult:
        key = jax.random.PRNGKey(cfg.seed + 2)
        Xtr, ytr = data["X_train"], data["y_train"]
        m = self.num_teachers or cfg.num_parties
        rng = np.random.default_rng(cfg.seed)
        perm = rng.permutation(len(Xtr))
        states = []
        for sub in np.array_split(perm, m):
            key, kk = jax.random.split(key)
            states.append(self.learner.fit(kk, Xtr[sub], ytr[sub]))
        preds = jnp.stack([self.learner.predict(st, data["X_public"])
                           for st in states])
        vote = teacher_vote(preds, cfg.num_classes)
        key, kk = jax.random.split(key)
        st = self.learner.fit(kk, data["X_public"],
                              np.asarray(vote.labels))
        acc = accuracy(self.learner, st, data["X_test"], data["y_test"])
        return StrategyResult(self.name, acc, state=st)


@dataclass
class IterativeStrategy:
    """Multi-round baselines: FedAvg / FedProx / SCAFFOLD (the free
    function ``core.baselines.run_iterative`` is now a wrapper over
    this).  ``cfg`` supplies the federation shape (parties, beta) when
    ``party_indices`` is not given."""
    net: Any
    icfg: Any                           # core.baselines.IterConfig
    init_params: Any = None
    eval_every: int = 1
    label: Optional[str] = None

    @property
    def name(self) -> str:
        return self.label or self.icfg.algo

    def run(self, data, cfg=None, *, party_indices=None) -> StrategyResult:
        from repro.core.baselines import (_local_adam, _local_scaffold,
                                          _wavg)
        from repro.core.learners import _pad_pow2

        icfg = self.icfg
        num_parties = cfg.num_parties if cfg is not None else 10
        beta = cfg.beta if cfg is not None else 0.5
        key = jax.random.PRNGKey(icfg.seed + 3)
        Xtr, ytr = data["X_train"], data["y_train"]
        if party_indices is None:
            party_indices = dirichlet_partition(ytr, num_parties, beta,
                                                icfg.seed)
        padded = [_pad_pow2(Xtr[ix], ytr[ix]) for ix in party_indices]
        sizes = np.array([len(ix) for ix in party_indices], np.float64)

        key, kk = jax.random.split(key)
        g_params = (self.init_params if self.init_params is not None
                    else self.net.init(kk))
        if icfg.algo == "scaffold":
            zeros = jax.tree.map(jnp.zeros_like, g_params)
            c_global = zeros
            c_parties = [zeros] * len(party_indices)

        Xte, yte = jnp.asarray(data["X_test"]), np.asarray(data["y_test"])
        accs: List[float] = []
        for r in range(icfg.rounds):
            locals_, new_cs = [], []
            for i, (Xp, yp, mask) in enumerate(padded):
                key, kk = jax.random.split(key)
                if icfg.algo == "scaffold":
                    p_i, c_i = _local_scaffold(self.net, icfg, kk, g_params,
                                               Xp, yp, mask, c_global,
                                               c_parties[i])
                    new_cs.append(c_i)
                else:
                    p_i = _local_adam(self.net, icfg, kk, g_params, Xp, yp,
                                      mask)
                locals_.append(p_i)
            g_params = _wavg(locals_, sizes)
            if icfg.algo == "scaffold":
                delta = [jax.tree.map(lambda a, b: a - b, cn, co)
                         for cn, co in zip(new_cs, c_parties)]
                c_parties = new_cs
                c_global = jax.tree.map(
                    lambda cg, *ds: cg + sum(ds) / len(party_indices),
                    c_global, *delta)
            if (r + 1) % self.eval_every == 0:
                preds = np.asarray(
                    jnp.argmax(self.net.apply(g_params, Xte), -1))
                accs.append(float((preds == yte).mean()))
        return StrategyResult(self.name, accs[-1] if accs else float("nan"),
                              state=g_params,
                              meta={"acc_per_round": accs})
