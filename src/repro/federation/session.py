"""FedKTSession: drives the paper's single communication round.

The session owns everything that spans the party/server boundary —
PRNG threading, the query-budget split, privacy accounting, and round
metrics — while Party/Server own their protocol sides and an Engine
owns teacher execution.  One session == one round == one result:

    session = FedKTSession(learner, data, cfg, engine="vmap")
    result = session.run()        # RoundResult

Seed contract: with ``engine="loop"`` the session reproduces the legacy
``run_fedkt`` accuracy and epsilon bit-for-bit at a fixed cfg.seed
(test-enforced in tests/test_federation.py).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List

import jax
import numpy as np

from repro.configs.base import FedKTConfig
from repro.core.learners import accuracy
from repro.core.partition import dirichlet_partition
from repro.federation.engines import get_engine
from repro.federation.messages import (PartyUpdate, RoundResult,
                                       label_wire_bytes)
from repro.federation.party import Party
from repro.federation.server import Server


def query_budget(cfg: FedKTConfig, num_public: int):
    """(party, server) query counts.  The noised side of the protocol
    answers only a ``query_fraction`` of D_aux — the DP budget knob."""
    frac = max(1, int(num_public * cfg.query_fraction))
    tq_party = num_public if cfg.privacy_level != "L2" else frac
    tq_server = num_public if cfg.privacy_level != "L1" else frac
    return tq_party, tq_server


class FedKTSession:
    """One FedKT round over in-process array data.

    data: dict with X_train/y_train/X_public/X_test/y_test arrays.
    engine: "loop" | "vmap" | an engines.Engine instance.
    """

    def __init__(self, learner, data: Dict[str, np.ndarray],
                 cfg: FedKTConfig, *, student_learner=None,
                 final_learner=None, engine="loop", party_indices=None):
        self.learner = learner
        self.student_learner = student_learner or learner
        self.final_learner = final_learner or learner
        self.data = data
        self.cfg = cfg
        self.engine = get_engine(engine)

        ytr = data["y_train"]
        if party_indices is None:
            party_indices = dirichlet_partition(ytr, cfg.num_parties,
                                                cfg.beta, cfg.seed)
        self.parties = [
            Party(party_id=i, X=data["X_train"], y=ytr, indices=ix,
                  cfg=cfg, learner=self.learner,
                  student_learner=self.student_learner)
            for i, ix in enumerate(party_indices)]
        self.server = Server(cfg, self.student_learner, self.final_learner)
        self.tq_party, self.tq_server = query_budget(cfg,
                                                     len(data["X_public"]))

    def run(self, verbose: bool = False) -> RoundResult:
        cfg = self.cfg
        key = jax.random.PRNGKey(cfg.seed)
        Xpub = self.data["X_public"]

        t0 = time.time()
        updates: List[PartyUpdate] = []
        for party in self.parties:
            upd, key = party.local_round(key, Xpub, self.tq_party,
                                         self.engine)
            updates.append(upd)
            if verbose:
                print(f"party {party.party_id}: {party.num_examples} "
                      f"examples, {cfg.num_partitions}x{cfg.num_subsets} "
                      f"teachers trained")
        t_parties = time.time() - t0

        t0 = time.time()
        final_state, vote, key = self.server.aggregate(
            key, updates, Xpub, self.tq_server, engine=self.engine)
        t_server = time.time() - t0

        acc = accuracy(self.final_learner, final_state,
                       self.data["X_test"], self.data["y_test"])
        eps = self.server.epsilon(vote, updates)

        meta: Dict[str, Any] = {
            "party_sizes": [p.num_examples for p in self.parties],
            "engine": self.engine.name,
            "queries": {"party": self.tq_party, "server": self.tq_server},
            "seconds": {"parties": round(t_parties, 3),
                        "server": round(t_server, 3)},
            "wire_bytes": {
                "updates": int(sum(u.wire_bytes() for u in updates)),
                "labels": label_wire_bytes(self.tq_party) * len(updates),
            },
        }
        return RoundResult(final_state=final_state, accuracy=acc,
                           student_states=[u.student_states
                                           for u in updates],
                           epsilon=eps, meta=meta)
