"""FedKTSession: drives the paper's single communication round.

The session owns everything that spans the party/server boundary —
PRNG threading, the query-budget split, privacy accounting, and round
metrics — while Party/Server own their protocol sides, an Engine owns
teacher execution, and a Transport owns WHERE parties run and how their
one PartyUpdate message travels (serialized through the wire codec in
every mode).  One session == one round == one result:

    session = FedKTSession(learner, data, cfg, engine="vmap")
    result = session.run()        # RoundResult

    # heterogeneous silos: each party brings its OWN learner and engine
    # (a PartyBinding) — the vote layout is learner-agnostic integer
    # counts, so rf + gbdt + nn ensemble in one round
    from repro.federation.bindings import PartyBinding
    FedKTSession([PartyBinding(RFLearner(num_classes=2)),
                  PartyBinding(GBDTLearner(), engine="vmap"),
                  PartyBinding(nn_learner, engine="vmap")],
                 data, cfg, final_learner=nn_learner).run()

    # cross-process silos: each party's round in its own interpreter,
    # fanned out over ``parallelism`` workers
    FedKTSession(learner, data, cfg, transport="subprocess",
                 parallelism=4).run()

    # fleet scale: parties deliver over TCP, the server folds each
    # arriving update into ONE running vote histogram (constant memory
    # in the party count with retain_students=False), stragglers are
    # dropped at the deadline once ``min_parties`` arrived
    from repro.federation.net import SocketTransport
    FedKTSession(learner, data, cfg, retain_students=False,
                 transport=SocketTransport(parallelism=8, deadline_s=60,
                                           min_parties=90)).run()

Every transport's updates are folded through the SAME
``StreamingVoteAggregate`` — a transport with ``streams = True``
(socket) folds per arrival, the others fold the finished list — so the
batch and streaming servers cannot diverge.

Seed contract: with ``engine="loop"`` the session reproduces the legacy
``run_fedkt`` accuracy and epsilon bit-for-bit at a fixed cfg.seed, and
every transport reproduces the in-process result bit-for-bit — party
keys are precomputed from the serial schedule, so fan-out order never
changes any party's randomness, and the vote histogram is an integer
sum, so arrival order cannot change it either (test-enforced in
tests/test_federation.py, tests/test_transport.py, tests/test_net.py).
"""
from __future__ import annotations

import time
from typing import Any, Dict

import jax
import numpy as np

from repro.configs.base import FedKTConfig
from repro.core.learners import accuracy
from repro.core.partition import dirichlet_partition
from repro.federation.bindings import resolve_bindings
from repro.federation.engines import get_engine
from repro.federation.messages import RoundResult
from repro.federation.party import Party
from repro.federation.server import Server
from repro.federation.transport import get_transport


def party_starting_keys(parties, seed: int):
    """Every party's starting key (the serial loop's exact split
    positions, played forward without training) plus the key the server
    side continues from.  Shared with launch/federate.py: a remote
    party derives ITS key from the same schedule, so a cross-host round
    reproduces the in-process one seed-for-seed."""
    key = jax.random.PRNGKey(seed)
    keys = []
    for party in parties:
        keys.append(key)
        key = party.advance_key(key)
    return keys, key


def query_budget(cfg: FedKTConfig, num_public: int):
    """(party, server) query counts.  The noised side of the protocol
    answers only a ``query_fraction`` of D_aux — the DP budget knob."""
    frac = max(1, int(num_public * cfg.query_fraction))
    tq_party = num_public if cfg.privacy_level != "L2" else frac
    tq_server = num_public if cfg.privacy_level != "L1" else frac
    return tq_party, tq_server


class FedKTSession:
    """One FedKT round over in-process array data.

    learner: a single Learner (the homogeneous shorthand — every party
        gets the same binding, exactly the pre-binding behavior) OR a
        sequence of ``bindings.PartyBinding``, one per party, for
        heterogeneous ensembles (each silo brings its own learner and
        engine; the (T, U) integer vote layout is the only cross-party
        contract, enforced at aggregation time).
    data: dict with X_train/y_train/X_public/X_test/y_test arrays.
    engine: "loop" | "vmap" | an engines.Engine instance — the default
        engine for bindings that don't name their own.
    final_learner: trains on the server's voted labels; defaults to the
        (first binding's) teacher learner.
    transport: "inprocess" | "thread" | "subprocess" | "socket" | a
        transport.Transport instance — where the party rounds run and
        how their updates cross the party/server boundary.  Pass a
        ``net.SocketTransport(...)`` instance to set the fleet knobs
        (deadline_s, min_parties, backoff).
    parallelism: worker count for the fan-out transports (defaults to
        one worker per party — the socket transport caps at 8; must be
        omitted when passing a transport instance).
    retain_students: keep every party's student states in the
        RoundResult (the default, and the historical behavior).  False
        drops each update after it is folded into the running vote
        aggregate — constant server memory in the party count, the
        fleet-scale mode.
    """

    def __init__(self, learner, data: Dict[str, np.ndarray],
                 cfg: FedKTConfig, *, student_learner=None,
                 final_learner=None, engine="loop", party_indices=None,
                 transport="inprocess", parallelism=None,
                 retain_students=True):
        self.bindings, self.final_learner = resolve_bindings(
            learner, student_learner=student_learner, engine=engine,
            num_parties=cfg.num_parties, final_learner=final_learner)
        # the homogeneous shorthand's session-wide fields (every
        # binding is the same one there); heterogeneous sessions should
        # read self.bindings instead
        self.learner = self.bindings[0].learner
        self.student_learner = self.bindings[0].student_learner
        self.data = data
        self.cfg = cfg
        self.engine = get_engine(engine)
        self.transport = get_transport(transport, parallelism)
        self.retain_students = retain_students

        ytr = data["y_train"]
        if party_indices is None:
            party_indices = dirichlet_partition(ytr, cfg.num_parties,
                                                cfg.beta, cfg.seed)
        self.parties = [
            Party(party_id=i, X=data["X_train"], y=ytr, indices=ix,
                  cfg=cfg, learner=b.learner,
                  student_learner=b.student_learner, engine=b.engine)
            for i, (ix, b) in enumerate(zip(party_indices,
                                            self.bindings))]
        self.server = Server(cfg, self.student_learner,
                             self.final_learner,
                             bindings=dict(enumerate(self.bindings)))
        self.tq_party, self.tq_server = query_budget(cfg,
                                                     len(data["X_public"]))

    def run(self, verbose: bool = False) -> RoundResult:
        cfg = self.cfg
        Xpub = self.data["X_public"]
        party_keys, key = party_starting_keys(self.parties, cfg.seed)
        agg = self.server.make_aggregate(
            Xpub, self.tq_server, self.engine,
            retain_students=self.retain_students)
        streaming = getattr(self.transport, "streams", False)

        def fold(upd):
            agg.add(upd)
            if verbose:
                print(f"party {upd.party_id}: {upd.num_examples} "
                      f"examples, {upd.meta['num_teachers']} teachers "
                      f"trained, {upd.meta['encoded_bytes']} wire bytes")

        t0 = time.time()
        # engine=None: every party runs under its OWN bound engine (the
        # heterogeneous contract; in the homogeneous shorthand all
        # bindings share the session engine, so nothing changes)
        if streaming:
            # the server folds each update the moment it arrives; party
            # training and aggregation overlap, so "parties" time IS the
            # whole collect-and-fold phase
            for upd in self.transport.stream_round(
                    self.parties, party_keys, Xpub, self.tq_party,
                    None):
                fold(upd)
            t_parties = time.time() - t0
            t0 = time.time()
        else:
            updates = self.transport.run_round(
                self.parties, party_keys, Xpub, self.tq_party, None)
            t_parties = time.time() - t0
            t0 = time.time()
            for upd in updates:
                fold(upd)
        final_state, vote, votes, key = self.server.finalize_all(key, agg)
        t_server = time.time() - t0

        acc = accuracy(self.final_learner, final_state,
                       self.data["X_test"], self.data["y_test"])
        # per-domain breakdown: one VoteResult + one epsilon fold per
        # vote domain (a legacy round has exactly one entry, and the
        # top-level fields are that entry's)
        by_domain: Dict[str, Dict[str, Any]] = {}
        for dom in agg.domains():
            v = votes[dom.ident]
            by_domain[dom.ident] = {
                "domain": dom,
                "vote": v,
                "labels": np.asarray(v.labels),
                "epsilon": agg.epsilon(v),
                "parties": agg.domain_parties(dom),
                "student_states": agg.student_states_for(dom),
            }
        # session-level bound: privacy composes across domains by max —
        # each domain's fold already max-composes its own parties
        # (Thm 4), and in a single-domain round this IS that domain's
        # epsilon, unchanged from the legacy path
        dom_eps = [row["epsilon"] for row in by_domain.values()
                   if row["epsilon"] is not None]
        eps = max(dom_eps) if dom_eps else None

        engine_names = sorted({b.engine.name for b in self.bindings})
        meta: Dict[str, Any] = {
            "party_sizes": [p.num_examples for p in self.parties],
            "engine": (engine_names[0] if len(engine_names) == 1
                       else "mixed"),
            # one row per party: which model family and engine each silo
            # brought to the round (identical rows = the homogeneous
            # shorthand)
            "party_bindings": [{"learner": b.kind,
                                "engine": b.engine.name}
                               for b in self.bindings],
            "transport": self.transport.name,
            "parallelism": getattr(self.transport, "parallelism", None),
            "queries": {"party": self.tq_party, "server": self.tq_server},
            "seconds": {"parties": round(t_parties, 3),
                        "server": round(t_server, 3)},
            # measured codec-framed bytes + raw-payload accounting,
            # summed over the parties whose updates actually arrived
            "wire_bytes": agg.wire_meta(),
            "num_updates": agg.num_parties,
        }
        if streaming:
            report = dict(self.transport.round_report)
            meta["socket"] = report
            # dropout accounting: stragglers excluded from the vote
            meta["dropped_parties"] = report.get("dropped", [])
        return RoundResult(final_state=final_state, accuracy=acc,
                           student_states=agg.student_states(),
                           epsilon=eps, meta=meta, by_domain=by_domain)
