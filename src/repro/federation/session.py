"""FedKTSession: drives the paper's single communication round.

The session owns everything that spans the party/server boundary —
PRNG threading, the query-budget split, privacy accounting, and round
metrics — while Party/Server own their protocol sides, an Engine owns
teacher execution, and a Transport owns WHERE parties run and how their
one PartyUpdate message travels (serialized through the wire codec in
every mode).  One session == one round == one result:

    session = FedKTSession(learner, data, cfg, engine="vmap")
    result = session.run()        # RoundResult

    # cross-process silos: each party's round in its own interpreter,
    # fanned out over ``parallelism`` workers
    FedKTSession(learner, data, cfg, transport="subprocess",
                 parallelism=4).run()

Seed contract: with ``engine="loop"`` the session reproduces the legacy
``run_fedkt`` accuracy and epsilon bit-for-bit at a fixed cfg.seed, and
every transport reproduces the in-process result bit-for-bit — party
keys are precomputed from the serial schedule, so fan-out order never
changes any party's randomness (test-enforced in
tests/test_federation.py and tests/test_transport.py).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List

import jax
import numpy as np

from repro.configs.base import FedKTConfig
from repro.core.learners import accuracy
from repro.core.partition import dirichlet_partition
from repro.federation import codec
from repro.federation.engines import get_engine
from repro.federation.messages import (LABEL_BYTES, PartyUpdate,
                                       RoundResult, TokenLabels)
from repro.federation.party import Party
from repro.federation.server import Server
from repro.federation.transport import get_transport


def query_budget(cfg: FedKTConfig, num_public: int):
    """(party, server) query counts.  The noised side of the protocol
    answers only a ``query_fraction`` of D_aux — the DP budget knob."""
    frac = max(1, int(num_public * cfg.query_fraction))
    tq_party = num_public if cfg.privacy_level != "L2" else frac
    tq_server = num_public if cfg.privacy_level != "L1" else frac
    return tq_party, tq_server


class FedKTSession:
    """One FedKT round over in-process array data.

    data: dict with X_train/y_train/X_public/X_test/y_test arrays.
    engine: "loop" | "vmap" | an engines.Engine instance.
    transport: "inprocess" | "thread" | "subprocess" | a
        transport.Transport instance — where the party rounds run and
        how their updates cross the party/server boundary.
    parallelism: worker count for the fan-out transports (defaults to
        one worker per party; must be omitted when passing a transport
        instance).
    """

    def __init__(self, learner, data: Dict[str, np.ndarray],
                 cfg: FedKTConfig, *, student_learner=None,
                 final_learner=None, engine="loop", party_indices=None,
                 transport="inprocess", parallelism=None):
        self.learner = learner
        self.student_learner = student_learner or learner
        self.final_learner = final_learner or learner
        self.data = data
        self.cfg = cfg
        self.engine = get_engine(engine)
        self.transport = get_transport(transport, parallelism)

        ytr = data["y_train"]
        if party_indices is None:
            party_indices = dirichlet_partition(ytr, cfg.num_parties,
                                                cfg.beta, cfg.seed)
        self.parties = [
            Party(party_id=i, X=data["X_train"], y=ytr, indices=ix,
                  cfg=cfg, learner=self.learner,
                  student_learner=self.student_learner)
            for i, ix in enumerate(party_indices)]
        self.server = Server(cfg, self.student_learner, self.final_learner)
        self.tq_party, self.tq_server = query_budget(cfg,
                                                     len(data["X_public"]))

    def _party_keys(self, key):
        """Every party's starting key (the serial loop's exact split
        positions, played forward without training) plus the key the
        server side continues from."""
        keys = []
        for party in self.parties:
            keys.append(key)
            key = party.advance_key(key)
        return keys, key

    def run(self, verbose: bool = False) -> RoundResult:
        cfg = self.cfg
        key = jax.random.PRNGKey(cfg.seed)
        Xpub = self.data["X_public"]

        t0 = time.time()
        party_keys, key = self._party_keys(key)
        updates: List[PartyUpdate] = self.transport.run_round(
            self.parties, party_keys, Xpub, self.tq_party, self.engine)
        t_parties = time.time() - t0
        if verbose:
            for party, upd in zip(self.parties, updates):
                print(f"party {party.party_id}: {party.num_examples} "
                      f"examples, {upd.meta['num_teachers']} teachers "
                      f"trained, {upd.meta['encoded_bytes']} wire bytes")

        t0 = time.time()
        final_state, vote, key = self.server.aggregate(
            key, updates, Xpub, self.tq_server, engine=self.engine)
        t_server = time.time() - t0

        acc = accuracy(self.final_learner, final_state,
                       self.data["X_test"], self.data["y_test"])
        eps = self.server.epsilon(vote, updates)

        meta: Dict[str, Any] = {
            "party_sizes": [p.num_examples for p in self.parties],
            "engine": self.engine.name,
            "transport": self.transport.name,
            "parallelism": getattr(self.transport, "parallelism", None),
            "queries": {"party": self.tq_party, "server": self.tq_server},
            "seconds": {"parties": round(t_parties, 3),
                        "server": round(t_server, 3)},
            "wire_bytes": {
                # measured: the codec-framed bytes that actually crossed
                # the party/server boundary (header + payload)
                "updates": int(sum(u.meta["encoded_bytes"]
                                   for u in updates)),
                # accounted: raw array payload (students + gap trace)
                "updates_payload": int(sum(u.wire_bytes()
                                           for u in updates)),
                # label answer, one per party: raw payload (one int32
                # per vote unit — per example for tabular learners, per
                # TOKEN on the LM path) and its codec-framed size
                "labels": int(sum(u.meta["num_query_labels"]
                                  for u in updates)) * LABEL_BYTES,
                "labels_framed": int(sum(
                    codec.labels_encoded_nbytes(TokenLabels(
                        party_id=u.party_id,
                        labels=jax.ShapeDtypeStruct(
                            (u.meta["num_query_labels"],), np.int32)))
                    for u in updates)),
            },
        }
        return RoundResult(final_state=final_state, accuracy=acc,
                           student_states=[u.student_states
                                           for u in updates],
                           epsilon=eps, meta=meta)
