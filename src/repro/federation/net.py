"""Socket federation: the one-shot protocol over real TCP connections.

The codec already made the wire format the boundary — a PartyUpdate is
one self-describing byte buffer.  This module moves that buffer over an
actual network:

  frame               : ``uint32 length | codec bytes``.  Length-prefixed
                        so a stream socket carries exactly one message;
                        the codec's own magic/version prefix inside the
                        payload rejects incompatible peers with a clear
                        error (codec.py).
  Coordinator         : an asyncio server that accepts party connections
                        CONCURRENTLY and hands each decoded update to a
                        consumer queue the moment it arrives — the
                        session folds it into the running vote aggregate
                        (federation/aggregate.py) while other parties
                        are still training.  Nothing ever holds all n
                        updates at once.
  SocketTransport     : the ``FedKTSession(transport="socket")`` backend.
                        By default it also SIMULATES the fleet: party
                        rounds fan out over a bounded thread pool on
                        this host, and each worker ships its update
                        through a real localhost TCP connection.  With
                        ``spawn=False`` it only coordinates — remote
                        parties connect from other processes/hosts via
                        ``run_party_client`` (see launch/federate.py and
                        docs/federation.md).

Straggler semantics: each party has until ``deadline_s`` (measured from
round start) to deliver its update.  When the deadline passes — or when
every remaining party has already failed outright — the round proceeds
if at least ``min_parties`` updates arrived; stragglers are EXCLUDED
from the vote and reported in ``round_report["dropped"]`` (surfaced as
session meta).  Below quorum the round raises ``QuorumError``.  Party
clients retry their connection with exponential backoff, so a
coordinator that is still binding its port never costs a party its
round.

Determinism: party keys are precomputed by the session (PR 3's
``advance_key`` discipline), updates are integer-folded in any arrival
order, and the server-side key threading never depends on the network —
so when all parties respond, the socket session is bit-identical to the
serial in-process loop (test-enforced in tests/test_net.py).
"""
from __future__ import annotations

import asyncio
import queue
import socket
import struct
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.federation.codec import encode_update
from repro.federation.messages import PartyUpdate
from repro.federation.transport import TransportBase, _decode_annotated

_LEN = struct.Struct("<I")
MAX_FRAME_BYTES = 1 << 31        # sanity bound on a length prefix
ACK, NAK = b"\x06", b"\x15"


class QuorumError(RuntimeError):
    """Round ended below ``min_parties`` arrived updates."""


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------
def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def send_update_frame(host: str, port: int, payload: bytes, *,
                      retries: int = 8, backoff_s: float = 0.05,
                      io_timeout_s: float = 60.0) -> None:
    """Ships one encoded PartyUpdate to the coordinator: connect (with
    exponential backoff — the coordinator may still be binding), send
    the length-prefixed frame, wait for the 1-byte ACK.  A NAK means
    the coordinator refused the frame (bad codec version, unknown or
    duplicate party, closed round) — not retryable."""
    if len(payload) >= MAX_FRAME_BYTES:
        raise ValueError(f"update frame of {len(payload)} bytes exceeds "
                         f"the {MAX_FRAME_BYTES}-byte frame bound")
    last_err: Optional[Exception] = None
    for attempt in range(retries):
        try:
            with socket.create_connection((host, port),
                                          timeout=io_timeout_s) as sock:
                sock.sendall(_LEN.pack(len(payload)) + payload)
                ack = _recv_exact(sock, 1)
            if ack == ACK:
                return
            raise ConnectionError(
                "coordinator refused the update frame (NAK) — "
                "incompatible codec version, unknown/duplicate party, "
                "or the round already closed")
        except (ConnectionRefusedError, ConnectionResetError,
                socket.timeout, TimeoutError) as err:
            last_err = err
            time.sleep(backoff_s * (2 ** attempt))
    raise ConnectionError(
        f"could not deliver update to {host}:{port} after {retries} "
        f"attempts: {last_err!r}")


def run_party_client(host: str, port: int, party, key, X_public,
                     num_queries: int, engine=None, *, retries: int = 8,
                     backoff_s: float = 0.05,
                     io_timeout_s: float = 60.0) -> int:
    """The remote-silo entry point: run this party's local round and
    ship the one resulting PartyUpdate to the coordinator.  Returns the
    framed byte count (what actually crossed the wire, minus the 4-byte
    length prefix).  ``engine=None`` runs the party's own bound engine
    — in a mixed fleet each silo's binding decides.  See
    launch/federate.py for the CLI wrapper."""
    upd, _ = party.local_round(key, X_public, num_queries, engine)
    payload = encode_update(upd)
    send_update_frame(host, port, payload, retries=retries,
                      backoff_s=backoff_s, io_timeout_s=io_timeout_s)
    return len(payload)


# ---------------------------------------------------------------------------
# Coordinator
# ---------------------------------------------------------------------------
class Coordinator:
    """Asyncio accept loop in a background thread.

    Decoded updates land on ``self.updates`` (a thread-safe queue) in
    ARRIVAL order, each annotated with its measured framed bytes; the
    consuming thread (SocketTransport.stream_round) owns deadlines and
    quorum.  Per-connection failures (truncated frame, codec version
    mismatch, unknown party) NAK that peer and are recorded in
    ``self.errors`` without disturbing the round.
    """

    def __init__(self, expected_ids: Sequence[int], *,
                 host: str = "127.0.0.1", port: int = 0,
                 expected_domains: Optional[Dict[int, Any]] = None):
        """``expected_domains`` (party_id -> VoteDomain) enables
        ACK-time domain validation: an update whose wire-declared domain
        contradicts what the party's binding derives is NAKed at
        delivery — the party finds out immediately, and the server never
        trains over it (the fold would refuse it later anyway;
        aggregate.py is the backstop)."""
        self.host, self._req_port = host, port
        self.expected = set(int(i) for i in expected_ids)
        self.expected_domains = dict(expected_domains or {})
        self.updates: "queue.Queue[PartyUpdate]" = queue.Queue()
        self.errors: List[str] = []
        self._seen: set = set()
        self._lock = threading.Lock()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._started = threading.Event()
        self._server: Optional[asyncio.AbstractServer] = None
        self.port: Optional[int] = None

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            try:
                nbytes = _LEN.unpack(await reader.readexactly(
                    _LEN.size))[0]
                if nbytes >= MAX_FRAME_BYTES:
                    raise ValueError(f"frame length {nbytes} exceeds "
                                     f"bound")
                payload = await reader.readexactly(nbytes)
                upd = _decode_annotated(payload)
                with self._lock:
                    if upd.party_id not in self.expected:
                        raise ValueError(f"unknown party "
                                         f"{upd.party_id}")
                    if upd.party_id in self._seen:
                        raise ValueError(f"duplicate update from party "
                                         f"{upd.party_id}")
                    exp = self.expected_domains.get(int(upd.party_id))
                    if (exp is not None and upd.domain is not None
                            and not exp.matches(upd.domain)):
                        raise ValueError(
                            f"vote-domain mismatch: party "
                            f"{upd.party_id} declares a "
                            f"{upd.domain.describe()}, but its session "
                            f"binding expects a {exp.describe()}")
                    self._seen.add(upd.party_id)
            except (asyncio.IncompleteReadError, ValueError) as err:
                self.errors.append(f"rejected connection: {err}")
                writer.write(NAK)
                await writer.drain()
                return
            writer.write(ACK)
            await writer.drain()
            self.updates.put(upd)
        finally:
            writer.close()

    async def _serve(self) -> None:
        self._server = await asyncio.start_server(
            self._handle, self.host, self._req_port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._started.set()
        async with self._server:
            await self._server.serve_forever()

    def start(self) -> "Coordinator":
        def runner():
            self._loop = asyncio.new_event_loop()
            asyncio.set_event_loop(self._loop)
            try:
                self._loop.run_until_complete(self._serve())
            except asyncio.CancelledError:
                pass
            finally:
                self._loop.close()
        self._thread = threading.Thread(target=runner, daemon=True,
                                        name="fedkt-coordinator")
        self._thread.start()
        if not self._started.wait(timeout=30.0):
            raise RuntimeError("coordinator failed to bind within 30s")
        return self

    def stop(self) -> None:
        """Stops accepting and joins the loop thread (idempotent).
        Late stragglers get connection-refused from here on."""
        loop = self._loop
        if loop is None or not loop.is_running():
            return

        def shutdown():
            for task in asyncio.all_tasks(loop):
                task.cancel()
        loop.call_soon_threadsafe(shutdown)
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        self._loop = None


# ---------------------------------------------------------------------------
# Transport
# ---------------------------------------------------------------------------
def _ship_round(party, key, X_public, num_queries, engine,
                host, port, retries, backoff_s, io_timeout_s):
    return run_party_client(host, port, party, key, X_public,
                            num_queries, engine, retries=retries,
                            backoff_s=backoff_s,
                            io_timeout_s=io_timeout_s)


class SocketTransport(TransportBase):
    """Fleet transport: parties deliver their updates over TCP, the
    coordinator streams them into the running aggregate as they land.

    parallelism : bound on concurrently-running simulated parties
                  (default min(n, 8) — a fleet of hundreds shares the
                  host, so one thread per party would thrash).
    host/port   : coordinator bind address (port=0 → ephemeral).
    deadline_s  : per-party deadline from round start; None waits
                  indefinitely (failed parties still end the wait).
    min_parties : quorum — proceed at the deadline with at least this
                  many updates, dropping stragglers.  None requires
                  every party.
    spawn       : False runs NO local parties; the coordinator waits for
                  remote ``run_party_client`` peers (cross-host mode).
    connect_retries / backoff_s / io_timeout_s : party-side client
                  knobs (exponential backoff between connect attempts).

    After each round, ``round_report`` holds the dropout accounting the
    session surfaces as ``meta["socket"]``.
    """
    name = "socket"
    streams = True

    @staticmethod
    def _expected_domains(parties, X_public) -> Dict[int, Any]:
        """party_id -> the VoteDomain each party's binding derives over
        the server-side query slice — what the coordinator validates
        arriving declarations against at ACK time.  Lazy imports:
        session lazy-loads this module through get_transport."""
        from repro.federation.domain import (fingerprint_queries,
                                             learner_domain)
        from repro.federation.session import query_budget
        Xpub = np.asarray(X_public)
        doms: Dict[int, Any] = {}
        fp_by_tq: Dict[int, Any] = {}    # hash each query slice once
        for p in parties:
            _, tq = query_budget(p.cfg, len(Xpub))
            if tq not in fp_by_tq:
                fp_by_tq[tq] = fingerprint_queries(Xpub[:tq])
            doms[int(p.party_id)] = learner_domain(
                p.student_learner, Xpub[:tq], p.cfg.num_classes,
                fingerprint=fp_by_tq[tq])
        return doms

    def __init__(self, parallelism: Optional[int] = None, *,
                 host: str = "127.0.0.1", port: int = 0,
                 deadline_s: Optional[float] = None,
                 min_parties: Optional[int] = None, spawn: bool = True,
                 connect_retries: int = 8, backoff_s: float = 0.05,
                 io_timeout_s: float = 60.0):
        self.parallelism = parallelism
        self.host, self.port = host, port
        self.deadline_s = deadline_s
        self.min_parties = min_parties
        self.spawn = spawn
        self.connect_retries = connect_retries
        self.backoff_s = backoff_s
        self.io_timeout_s = io_timeout_s
        self.round_report: Dict[str, Any] = {}

    def stream_round(self, parties, keys, X_public, num_queries,
                     engine) -> Iterator[PartyUpdate]:
        """Yields decoded PartyUpdates in ARRIVAL order, as they land.
        The consumer folds each into the streaming aggregate; this
        generator never accumulates updates."""
        expected = [int(p.party_id) for p in parties]
        coord = Coordinator(
            expected, host=self.host, port=self.port,
            expected_domains=self._expected_domains(parties, X_public)
        ).start()
        workers = min(len(parties), self.parallelism or 8)
        pool: Optional[ThreadPoolExecutor] = None
        failed: Dict[int, str] = {}
        failed_lock = threading.Lock()
        t0 = time.monotonic()
        try:
            if self.spawn:
                pool = ThreadPoolExecutor(
                    max_workers=workers,
                    thread_name_prefix="fedkt-party")
                Xpub = np.asarray(X_public)

                def _done(pid):
                    def cb(fut):
                        if fut.cancelled():
                            return
                        err = fut.exception()
                        if err is not None:
                            with failed_lock:
                                failed[pid] = repr(err)
                    return cb

                for party, key in zip(parties, keys):
                    fut = pool.submit(
                        _ship_round, party, key, Xpub, num_queries,
                        engine, self.host, coord.port,
                        self.connect_retries, self.backoff_s,
                        self.io_timeout_s)
                    fut.add_done_callback(_done(int(party.party_id)))

            arrived: List[int] = []
            arrival_s: Dict[int, float] = {}
            bytes_by_party: Dict[int, int] = {}
            quorum = (len(expected) if self.min_parties is None
                      else self.min_parties)
            while len(arrived) < len(expected):
                with failed_lock:
                    nfailed = len(failed)
                if len(arrived) + nfailed == len(expected):
                    break                     # nobody left to wait for
                elapsed = time.monotonic() - t0
                late = (self.deadline_s is not None
                        and elapsed >= self.deadline_s)
                try:
                    # at the deadline, still drain updates that already
                    # landed — only parties with nothing delivered drop
                    upd = coord.updates.get_nowait() if late \
                        else coord.updates.get(timeout=0.05)
                except queue.Empty:
                    if late:
                        break                 # deadline: quorum decides
                    continue
                arrived.append(int(upd.party_id))
                arrival_s[int(upd.party_id)] = round(
                    time.monotonic() - t0, 3)
                bytes_by_party[int(upd.party_id)] = \
                    upd.meta["encoded_bytes"]
                yield upd

            dropped = sorted(set(expected) - set(arrived))
            with failed_lock:
                report_failed = dict(failed)
            self.round_report = {
                "port": coord.port,
                "expected": len(expected),
                "arrived": arrived,            # arrival order
                "dropped": dropped,
                "failed": report_failed,       # party_id -> error
                "deadline_s": self.deadline_s,
                "min_parties": self.min_parties,
                "quorum": quorum,
                "framed_bytes": bytes_by_party,
                "arrival_s": arrival_s,
                "rejected": list(coord.errors),
            }
            if len(arrived) < quorum:
                raise QuorumError(
                    f"round ended with {len(arrived)}/{len(expected)} "
                    f"updates (quorum {quorum}); missing parties "
                    f"{dropped}"
                    + (f"; failures: {report_failed}" if report_failed
                       else ""))
        finally:
            coord.stop()
            if pool is not None:
                # never block the round on stragglers we already
                # dropped: queued parties are cancelled, running ones
                # get connection-refused when they try to deliver
                pool.shutdown(wait=False, cancel_futures=True)

    def run_round(self, parties, keys, X_public, num_queries, engine):
        """List form of the round for the non-streaming server path
        (Transport contract: party order)."""
        updates = list(self.stream_round(parties, keys, X_public,
                                         num_queries, engine))
        return sorted(updates, key=lambda u: u.party_id)
