"""Socket federation: the one-shot protocol over real TCP connections.

The codec already made the wire format the boundary — a PartyUpdate is
one self-describing byte buffer.  This module moves that buffer over an
actual network:

  frame               : ``uint32 length | codec bytes``.  Length-prefixed
                        so a stream socket carries exactly one message;
                        the codec's own magic/version prefix inside the
                        payload rejects incompatible peers with a clear
                        error, and its crc32 trailer catches bytes
                        mangled in flight (codec.py).
  reply               : 1 byte ACK, or NAK followed by a one-byte
                        reason code (see the NAK_* table) so the party
                        can tell a retryable refusal (``corrupt`` — the
                        frame was damaged in transit, send it again)
                        from a fatal one (``unknown-party``,
                        ``domain-mismatch`` — retrying cannot help).
  Coordinator         : an asyncio server that accepts party connections
                        CONCURRENTLY and hands each decoded update to a
                        consumer queue the moment it arrives — the
                        session folds it into the running vote aggregate
                        (federation/aggregate.py) while other parties
                        are still training.  Nothing ever holds all n
                        updates at once.
  SocketTransport     : the ``FedKTSession(transport="socket")`` backend.
                        By default it also SIMULATES the fleet: party
                        rounds fan out over a bounded thread pool on
                        this host, and each worker ships its update
                        through a real localhost TCP connection.  With
                        ``spawn=False`` it only coordinates — remote
                        parties connect from other processes/hosts via
                        ``run_party_client`` (see launch/federate.py and
                        docs/federation.md).

Crash safety: with ``journal_path=`` set, every accepted frame is
fsync'd to a write-ahead RoundJournal (federation/journal.py) BEFORE
the ACK is written or the update folds.  A coordinator restarted with
``resume=True`` replays the journal (crc-validated, torn tail
truncated), refolds the already-arrived parties, and waits only for
the missing ones; the recovery is accounted in ``round_report``
(``resumed``, ``replayed_parties``, ``corrupt_records_dropped``).
Delivery is idempotent: a retransmit whose bytes match what the
journal holds for that party is RE-ACKED, never re-folded — so a party
that lost an ACK may safely send-until-ACK (``re_acked`` counts them).
Fault injection (federation/faults.py) plugs in as ``fault_hook``: a
hook returning True at the "journaled" event kills the coordinator in
the exact append->ACK/fold window the journal must cover.

Straggler semantics: each party has until ``deadline_s`` (measured from
round start) to deliver its update.  When the deadline passes — or when
every remaining party has already failed outright — the round proceeds
if at least ``min_parties`` updates arrived; stragglers are EXCLUDED
from the vote and reported in ``round_report["dropped"]`` (surfaced as
session meta).  Below quorum the round raises ``QuorumError``.  Party
clients retry their connection with exponential backoff, so a
coordinator that is still binding its port never costs a party its
round.

Determinism: party keys are precomputed by the session (PR 3's
``advance_key`` discipline), updates are integer-folded in any arrival
order, and the server-side key threading never depends on the network —
so when all parties respond, the socket session is bit-identical to the
serial in-process loop, and a crash-resumed round is bit-identical to
an uninterrupted one (test-enforced in tests/test_net.py and
tests/test_faults.py).
"""
from __future__ import annotations

import asyncio
import hashlib
import queue
import socket
import struct
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import (Any, Callable, Dict, Iterator, List, Optional,
                    Sequence)

import numpy as np

from repro.federation.codec import (CorruptFrameError, TruncatedFrameError,
                                    VersionMismatchError, encode_update)
from repro.federation.journal import RoundJournal
from repro.federation.messages import PartyUpdate
from repro.federation.transport import TransportBase, _decode_annotated

_LEN = struct.Struct("<I")
MAX_FRAME_BYTES = 1 << 31        # sanity bound on a length prefix
ACK, NAK = b"\x06", b"\x15"

# NAK reason codes: the byte after NAK.  ``corrupt`` is the only
# retryable refusal — the bytes were damaged in transit and a clean
# retransmit can succeed; every other reason is a property of the
# update or the round, and retrying the same frame cannot change it.
NAK_PROTOCOL = 0          # undecodable / wrong codec version / framing
NAK_DUPLICATE = 1         # party already folded, retransmit differs
NAK_DOMAIN_MISMATCH = 2   # declared vote domain contradicts binding
NAK_UNKNOWN_PARTY = 3     # party id not in this round
NAK_CORRUPT = 4           # crc failure / truncation: retransmit
NAK_REASON_NAMES = {
    NAK_PROTOCOL: "protocol",
    NAK_DUPLICATE: "duplicate",
    NAK_DOMAIN_MISMATCH: "domain-mismatch",
    NAK_UNKNOWN_PARTY: "unknown-party",
    NAK_CORRUPT: "corrupt",
}
RETRYABLE_NAKS = frozenset({NAK_CORRUPT})


class QuorumError(RuntimeError):
    """Round ended below ``min_parties`` arrived updates."""


class UpdateRefused(ConnectionError):
    """The coordinator NAKed the frame.  ``reason`` is the NAK_* code
    (None when the peer closed before sending one); ``retryable`` says
    whether a retransmit of the same update can ever succeed."""

    def __init__(self, reason: Optional[int]):
        self.reason = reason
        self.retryable = reason in RETRYABLE_NAKS
        name = NAK_REASON_NAMES.get(reason, "unspecified") \
            if reason is not None else "unspecified"
        kind = "retryable" if self.retryable else "fatal"
        super().__init__(
            f"coordinator refused the update frame (NAK, reason: "
            f"{name}, {kind})")


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------
def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def _recv_reason(sock: socket.socket) -> Optional[int]:
    """The optional reason byte after a NAK; None if the peer closed
    without one (a pre-reason-code coordinator, or a dying one)."""
    try:
        b = sock.recv(1)
    except OSError:
        return None
    return b[0] if b else None


def send_update_frame(host: str, port: int, payload: bytes, *,
                      retries: int = 8, backoff_s: float = 0.05,
                      io_timeout_s: float = 60.0) -> None:
    """Ships one encoded PartyUpdate to the coordinator: connect (with
    exponential backoff — the coordinator may still be binding), send
    the length-prefixed frame, wait for the ACK.  Connection failures
    and retryable NAKs (reason ``corrupt``: the frame was damaged in
    flight) are retried; a fatal NAK (unknown party, duplicate, domain
    mismatch, protocol) raises ``UpdateRefused`` IMMEDIATELY with the
    reason named — no backoff is slept after a fatal refusal or after
    the final attempt."""
    if len(payload) >= MAX_FRAME_BYTES:
        raise ValueError(f"update frame of {len(payload)} bytes exceeds "
                         f"the {MAX_FRAME_BYTES}-byte frame bound")
    last_err: Optional[Exception] = None
    for attempt in range(retries):
        try:
            with socket.create_connection((host, port),
                                          timeout=io_timeout_s) as sock:
                sock.sendall(_LEN.pack(len(payload)) + payload)
                reply = _recv_exact(sock, 1)
                reason = None if reply == ACK else _recv_reason(sock)
        except (OSError, TimeoutError) as err:
            last_err = err
        else:
            if reply == ACK:
                return
            refusal = UpdateRefused(reason)
            if not refusal.retryable:
                raise refusal
            last_err = refusal
        if attempt + 1 < retries:
            time.sleep(backoff_s * (2 ** attempt))
    raise ConnectionError(
        f"could not deliver update to {host}:{port} after {retries} "
        f"attempts: {last_err!r}")


def run_party_client(host: str, port: int, party, key, X_public,
                     num_queries: int, engine=None, *, retries: int = 8,
                     backoff_s: float = 0.05,
                     io_timeout_s: float = 60.0) -> int:
    """The remote-silo entry point: run this party's local round and
    ship the one resulting PartyUpdate to the coordinator.  Returns the
    framed byte count (what actually crossed the wire, minus the 4-byte
    length prefix).  ``engine=None`` runs the party's own bound engine
    — in a mixed fleet each silo's binding decides.  Delivery is
    send-until-ACK safe: if the coordinator journaled the update but
    the ACK was lost, the retransmit is re-ACKed, never double-folded.
    See launch/federate.py for the CLI wrapper."""
    upd, _ = party.local_round(key, X_public, num_queries, engine)
    payload = encode_update(upd)
    send_update_frame(host, port, payload, retries=retries,
                      backoff_s=backoff_s, io_timeout_s=io_timeout_s)
    return len(payload)


# ---------------------------------------------------------------------------
# Coordinator
# ---------------------------------------------------------------------------
class Coordinator:
    """Asyncio accept loop in a background thread.

    Decoded updates land on ``self.updates`` (a thread-safe queue) in
    ARRIVAL order, each annotated with its measured framed bytes; the
    consuming thread (SocketTransport.stream_round) owns deadlines and
    quorum.  Per-connection failures (truncated frame, codec version
    mismatch, unknown party) NAK that peer with a reason byte and are
    recorded in ``self.errors`` without disturbing the round.

    With ``journal_path=`` every accepted frame is fsync'd to a
    RoundJournal before the ACK/fold; ``resume=True`` replays an
    existing journal at start() — replayed updates are queued before
    the socket even binds, ``self.replayed`` lists their parties, and
    only the missing parties are waited for.  A retransmit whose bytes
    match the journaled (or, journal-less, the digest-remembered)
    frame is re-ACKed idempotently (``self.re_acked``).

    ``fault_hook(event, party_id) -> bool`` is the chaos injection
    point (federation/faults.py): returning True at event "journaled"
    kills the coordinator after the journal append and before the
    ACK/fold — the party never hears back, the server thread dies, and
    only a resume can finish the round.
    """

    def __init__(self, expected_ids: Sequence[int], *,
                 host: str = "127.0.0.1", port: int = 0,
                 expected_domains: Optional[Dict[int, Any]] = None,
                 journal_path: Optional[str] = None,
                 resume: bool = False,
                 fault_hook: Optional[Callable[[str, int], bool]] = None):
        """``expected_domains`` (party_id -> VoteDomain) enables
        ACK-time domain validation: an update whose wire-declared domain
        contradicts what the party's binding derives is NAKed at
        delivery — the party finds out immediately, and the server never
        trains over it (the fold would refuse it later anyway;
        aggregate.py is the backstop)."""
        self.host, self._req_port = host, port
        self.expected = set(int(i) for i in expected_ids)
        self.expected_domains = dict(expected_domains or {})
        self.journal_path = journal_path
        self.resume = resume
        self.journal: Optional[RoundJournal] = None
        self.replayed: List[int] = []
        self.corrupt_records_dropped = 0
        self.re_acked: Dict[int, int] = {}
        self.killed = False
        self._fault_hook = fault_hook
        self.updates: "queue.Queue[PartyUpdate]" = queue.Queue()
        self.errors: List[str] = []
        self._seen: set = set()
        self._digest: Dict[int, bytes] = {}    # pid -> sha256(frame)
        self._lock = threading.Lock()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._started = threading.Event()
        self._server: Optional[asyncio.AbstractServer] = None
        self._kill_evt: Optional[asyncio.Event] = None
        self.port: Optional[int] = None

    # -- admission --------------------------------------------------------
    def _admit(self, payload: bytes):
        """The whole accept decision for one delivered frame, under the
        round lock: returns ``(reply_bytes, update_or_None)``.  A reply
        of None means the fault hook fired — the coordinator must die
        without answering (the journaled-but-unACKed crash window)."""
        try:
            upd = _decode_annotated(payload)
        except VersionMismatchError as err:
            self.errors.append(f"rejected connection: {err}")
            return NAK + bytes([NAK_PROTOCOL]), None
        except (TruncatedFrameError, CorruptFrameError) as err:
            self.errors.append(f"rejected connection: {err}")
            return NAK + bytes([NAK_CORRUPT]), None
        except ValueError as err:
            self.errors.append(f"rejected connection: {err}")
            return NAK + bytes([NAK_PROTOCOL]), None
        pid = int(upd.party_id)
        with self._lock:
            if pid not in self.expected:
                self.errors.append(f"rejected connection: unknown party "
                                   f"{pid}")
                return NAK + bytes([NAK_UNKNOWN_PARTY]), None
            if pid in self._seen:
                # sha256, NOT the frame's crc32: a v3 frame ends with
                # the crc of its own body, which makes crc32(frame) the
                # same constant residue for EVERY valid frame
                same = (hashlib.sha256(payload).digest()
                        == self._digest.get(pid))
                if same and self.journal is not None:
                    # digest agreement is necessary, byte identity is
                    # what a re-ACK actually promises
                    same = self.journal.frame_matches(pid, payload)
                if same:
                    self.re_acked[pid] = self.re_acked.get(pid, 0) + 1
                    return ACK, None     # lost-ACK retransmit: no fold
                self.errors.append(f"rejected connection: duplicate "
                                   f"update from party {pid} with "
                                   f"different bytes")
                return NAK + bytes([NAK_DUPLICATE]), None
            exp = self.expected_domains.get(pid)
            if (exp is not None and upd.domain is not None
                    and not exp.matches(upd.domain)):
                self.errors.append(
                    f"rejected connection: vote-domain mismatch: party "
                    f"{pid} declares a {upd.domain.describe()}, but its "
                    f"session binding expects a {exp.describe()}")
                return NAK + bytes([NAK_DOMAIN_MISMATCH]), None
            if self.journal is not None:
                self.journal.append(pid, payload)
            if (self._fault_hook is not None
                    and self._fault_hook("journaled", pid)):
                return None, None        # crash before ACK/fold
            self._seen.add(pid)
            self._digest[pid] = hashlib.sha256(payload).digest()
        return ACK, upd

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            try:
                nbytes = _LEN.unpack(await reader.readexactly(
                    _LEN.size))[0]
                if nbytes >= MAX_FRAME_BYTES:
                    self.errors.append(f"rejected connection: frame "
                                       f"length {nbytes} exceeds bound")
                    reply: Optional[bytes] = NAK + bytes([NAK_PROTOCOL])
                    upd = None
                else:
                    payload = await reader.readexactly(nbytes)
                    reply, upd = self._admit(payload)
            except asyncio.IncompleteReadError as err:
                # the frame never finished arriving (killed connection,
                # half-shipped bytes): retryable by definition
                self.errors.append(f"rejected connection: {err}")
                reply, upd = NAK + bytes([NAK_CORRUPT]), None
            if reply is None:
                self.killed = True       # fault hook: die unanswered
                if self._kill_evt is not None:
                    self._kill_evt.set()
                return
            if upd is not None:
                # queue BEFORE the ACK: if the ACK is lost on the wire
                # the update is still folded, and the retransmit hits
                # the idempotent re-ACK path instead of re-queueing
                self.updates.put(upd)
            writer.write(reply)
            await writer.drain()
        except (ConnectionError, OSError):
            pass                         # peer vanished mid-reply
        finally:
            writer.close()

    # -- lifecycle --------------------------------------------------------
    def _replay_journal(self) -> None:
        """Folds an existing journal back into the round state before
        the socket binds: every crc-valid record that still decodes is
        queued exactly as if its party had just delivered it."""
        self.journal = RoundJournal(self.journal_path,
                                    resume=self.resume)
        self.corrupt_records_dropped = self.journal.corrupt_records_dropped
        for pid, frame in self.journal.records:
            if pid not in self.expected:
                self.errors.append(f"journal replay: party {pid} is "
                                   f"not in this round; record ignored")
                continue
            try:
                upd = _decode_annotated(frame)
            except ValueError as err:
                # crc-valid yet undecodable (e.g. a codec the journal
                # outlived): drop it, let a fresh delivery re-arrive
                self.errors.append(f"journal replay: party {pid} record "
                                   f"undecodable ({err}); dropped")
                self.corrupt_records_dropped += 1
                continue
            self._seen.add(pid)
            self._digest[pid] = hashlib.sha256(frame).digest()
            self.replayed.append(pid)
            self.updates.put(upd)

    async def _serve(self) -> None:
        self._server = await asyncio.start_server(
            self._handle, self.host, self._req_port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._kill_evt = asyncio.Event()
        self._started.set()
        async with self._server:
            kill = asyncio.ensure_future(self._kill_evt.wait())
            serve = asyncio.ensure_future(self._server.serve_forever())
            done, pending = await asyncio.wait(
                {kill, serve}, return_when=asyncio.FIRST_COMPLETED)
            for task in pending:
                task.cancel()

    def start(self) -> "Coordinator":
        if self.journal_path is not None:
            self._replay_journal()

        def runner():
            self._loop = asyncio.new_event_loop()
            asyncio.set_event_loop(self._loop)
            try:
                self._loop.run_until_complete(self._serve())
            except asyncio.CancelledError:
                pass
            finally:
                if self.journal is not None:
                    self.journal.close()
                self._loop.close()
        self._thread = threading.Thread(target=runner, daemon=True,
                                        name="fedkt-coordinator")
        self._thread.start()
        if not self._started.wait(timeout=30.0):
            raise RuntimeError("coordinator failed to bind within 30s")
        return self

    def stop(self) -> None:
        """Stops accepting and joins the loop thread (idempotent).
        Late stragglers get connection-refused from here on."""
        loop = self._loop
        if loop is None or not loop.is_running():
            if self._thread is not None:
                self._thread.join(timeout=10.0)
            return

        def shutdown():
            for task in asyncio.all_tasks(loop):
                task.cancel()
        loop.call_soon_threadsafe(shutdown)
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        self._loop = None


# ---------------------------------------------------------------------------
# Transport
# ---------------------------------------------------------------------------
def _ship_round(party, key, X_public, num_queries, engine,
                host, port, retries, backoff_s, io_timeout_s):
    return run_party_client(host, port, party, key, X_public,
                            num_queries, engine, retries=retries,
                            backoff_s=backoff_s,
                            io_timeout_s=io_timeout_s)


class SocketTransport(TransportBase):
    """Fleet transport: parties deliver their updates over TCP, the
    coordinator streams them into the running aggregate as they land.

    parallelism : bound on concurrently-running simulated parties
                  (default min(n, 8) — a fleet of hundreds shares the
                  host, so one thread per party would thrash).
    host/port   : coordinator bind address (port=0 → ephemeral).
    deadline_s  : per-party deadline from round start; None waits
                  indefinitely (failed parties still end the wait).
    min_parties : quorum — proceed at the deadline with at least this
                  many updates, dropping stragglers.  None requires
                  every party.
    spawn       : False runs NO local parties; the coordinator waits for
                  remote ``run_party_client`` peers (cross-host mode).
    connect_retries / backoff_s / io_timeout_s : party-side client
                  knobs (exponential backoff between connect attempts).
    journal_path: write-ahead journal file enabling crash recovery
                  (every accepted frame fsync'd before ACK/fold).
    resume      : replay an existing journal at round start; replayed
                  parties fold immediately, are NOT re-spawned, and are
                  not waited for.
    chaos_plan  : a faults.FaultPlan — spawned parties deliver through
                  an in-path ChaosProxy applying the plan's scripted
                  connection faults, and a coordinator-kill fault (if
                  scheduled) fires in the journal-append window.

    After each round, ``round_report`` holds the dropout AND recovery
    accounting the session surfaces as ``meta["socket"]``.
    """
    name = "socket"
    streams = True

    @staticmethod
    def _expected_domains(parties, X_public) -> Dict[int, Any]:
        """party_id -> the VoteDomain each party's binding derives over
        the server-side query slice — what the coordinator validates
        arriving declarations against at ACK time.  Lazy imports:
        session lazy-loads this module through get_transport."""
        from repro.federation.domain import (fingerprint_queries,
                                             learner_domain)
        from repro.federation.session import query_budget
        Xpub = np.asarray(X_public)
        doms: Dict[int, Any] = {}
        fp_by_tq: Dict[int, Any] = {}    # hash each query slice once
        for p in parties:
            _, tq = query_budget(p.cfg, len(Xpub))
            if tq not in fp_by_tq:
                fp_by_tq[tq] = fingerprint_queries(Xpub[:tq])
            doms[int(p.party_id)] = learner_domain(
                p.student_learner, Xpub[:tq], p.cfg.num_classes,
                fingerprint=fp_by_tq[tq])
        return doms

    def __init__(self, parallelism: Optional[int] = None, *,
                 host: str = "127.0.0.1", port: int = 0,
                 deadline_s: Optional[float] = None,
                 min_parties: Optional[int] = None, spawn: bool = True,
                 connect_retries: int = 8, backoff_s: float = 0.05,
                 io_timeout_s: float = 60.0,
                 journal_path: Optional[str] = None,
                 resume: bool = False, chaos_plan=None):
        self.parallelism = parallelism
        self.host, self.port = host, port
        self.deadline_s = deadline_s
        self.min_parties = min_parties
        self.spawn = spawn
        self.connect_retries = connect_retries
        self.backoff_s = backoff_s
        self.io_timeout_s = io_timeout_s
        self.journal_path = journal_path
        self.resume = resume
        self.chaos_plan = chaos_plan
        self.round_report: Dict[str, Any] = {}

    def stream_round(self, parties, keys, X_public, num_queries,
                     engine) -> Iterator[PartyUpdate]:
        """Yields decoded PartyUpdates in ARRIVAL order, as they land.
        The consumer folds each into the streaming aggregate; this
        generator never accumulates updates.  Replayed journal records
        are yielded first (they were queued before the socket bound);
        their parties are neither re-spawned nor waited for."""
        expected = [int(p.party_id) for p in parties]
        fault_hook = (self.chaos_plan.coordinator_hook()
                      if self.chaos_plan is not None else None)
        coord = Coordinator(
            expected, host=self.host, port=self.port,
            expected_domains=self._expected_domains(parties, X_public),
            journal_path=self.journal_path, resume=self.resume,
            fault_hook=fault_hook,
        ).start()
        replayed = set(coord.replayed)
        proxy = None
        deliver_port = coord.port
        if self.chaos_plan is not None:
            from repro.federation.faults import ChaosProxy
            proxy = ChaosProxy(self.host, coord.port,
                               self.chaos_plan).start()
            deliver_port = proxy.port
        workers = min(max(1, len(parties) - len(replayed)),
                      self.parallelism or 8)
        pool: Optional[ThreadPoolExecutor] = None
        failed: Dict[int, str] = {}
        failed_lock = threading.Lock()
        t0 = time.monotonic()
        try:
            if self.spawn:
                pool = ThreadPoolExecutor(
                    max_workers=workers,
                    thread_name_prefix="fedkt-party")
                Xpub = np.asarray(X_public)

                def _done(pid):
                    def cb(fut):
                        if fut.cancelled():
                            return
                        err = fut.exception()
                        if err is not None:
                            with failed_lock:
                                failed[pid] = repr(err)
                    return cb

                for party, key in zip(parties, keys):
                    if int(party.party_id) in replayed:
                        continue         # its update already folded
                    fut = pool.submit(
                        _ship_round, party, key, Xpub, num_queries,
                        engine, self.host, deliver_port,
                        self.connect_retries, self.backoff_s,
                        self.io_timeout_s)
                    fut.add_done_callback(_done(int(party.party_id)))

            arrived: List[int] = []
            arrival_s: Dict[int, float] = {}
            bytes_by_party: Dict[int, int] = {}
            quorum = (len(expected) if self.min_parties is None
                      else self.min_parties)
            while len(arrived) < len(expected):
                with failed_lock:
                    nfailed = len(failed)
                if len(arrived) + nfailed == len(expected):
                    break                     # nobody left to wait for
                elapsed = time.monotonic() - t0
                late = (self.deadline_s is not None
                        and elapsed >= self.deadline_s)
                try:
                    # at the deadline, still drain updates that already
                    # landed — only parties with nothing delivered drop
                    upd = coord.updates.get_nowait() if late \
                        else coord.updates.get(timeout=0.05)
                except queue.Empty:
                    if late:
                        break                 # deadline: quorum decides
                    continue
                arrived.append(int(upd.party_id))
                arrival_s[int(upd.party_id)] = round(
                    time.monotonic() - t0, 3)
                bytes_by_party[int(upd.party_id)] = \
                    upd.meta["encoded_bytes"]
                yield upd

            dropped = sorted(set(expected) - set(arrived))
            with failed_lock:
                report_failed = dict(failed)
            self.round_report = {
                "port": coord.port,
                "expected": len(expected),
                "arrived": arrived,            # arrival order
                "dropped": dropped,
                "failed": report_failed,       # party_id -> error
                "deadline_s": self.deadline_s,
                "min_parties": self.min_parties,
                "quorum": quorum,
                "framed_bytes": bytes_by_party,
                "arrival_s": arrival_s,
                "rejected": list(coord.errors),
                "journal": self.journal_path,
                "resumed": (coord.journal.resumed
                            if coord.journal is not None else False),
                "replayed_parties": sorted(replayed),
                "corrupt_records_dropped": coord.corrupt_records_dropped,
                "re_acked": dict(coord.re_acked),
                "coordinator_killed": coord.killed,
            }
            if self.chaos_plan is not None:
                self.round_report["chaos"] = list(self.chaos_plan.log)
            if len(arrived) < quorum:
                raise QuorumError(
                    f"round ended with {len(arrived)}/{len(expected)} "
                    f"updates (quorum {quorum}); missing parties "
                    f"{dropped}"
                    + (f"; failures: {report_failed}" if report_failed
                       else ""))
        finally:
            if proxy is not None:
                proxy.stop()
            coord.stop()
            if pool is not None:
                # never block the round on stragglers we already
                # dropped: queued parties are cancelled, running ones
                # get connection-refused when they try to deliver
                pool.shutdown(wait=False, cancel_futures=True)

    def run_round(self, parties, keys, X_public, num_queries, engine):
        """List form of the round for the non-streaming server path
        (Transport contract: party order)."""
        updates = list(self.stream_round(parties, keys, X_public,
                                         num_queries, engine))
        return sorted(updates, key=lambda u: u.party_id)
