"""Execution engines: HOW a party's teachers get trained and queried.

The protocol (who sends what, once) lives in party.py / server.py /
session.py; an Engine only decides how a batch of teachers is fit and
how a trained bank predicts the public queries:

  LoopEngine : one ``learner.fit`` per teacher, serially — the seed
               semantics of the original ``run_fedkt`` loop.
  VmapEngine : stacks all given teachers into one ``jax.vmap``-ed fit
               over a shared pow2-padded bucket.  The Party hands it
               its full s*t teacher grid, so the n*s*t sequential jit
               dispatches of the serial loop collapse to one batched
               dispatch per party — the headline wall-clock win (see
               BENCH_federation_engines.json).

PRNG contract: engines never split keys.  The Party precomputes the
legacy loop's exact key schedule (one split per teacher, in partition/
subset order) and passes one key per teacher, so switching engines
never changes which key a teacher sees.  When every subset pads to the
same pow2 bucket the two engines are bit-identical; otherwise they may
differ in trailing pad size and are only required to agree on vote
labels (test-enforced).
"""
from __future__ import annotations

from typing import Any, Protocol, Sequence, Tuple

import jax
import jax.numpy as jnp


class Engine(Protocol):
    """Pluggable teacher-execution backend."""
    name: str

    def fit_teachers(self, keys: Sequence[Any], learner,
                     datasets: Sequence[Tuple[Any, Any]]) -> Any:
        """Trains one teacher per (X, y) dataset with the paired key.
        Returns an opaque teacher bank."""
        ...

    def slice_bank(self, bank, start: int, stop: int) -> Any:
        """The sub-bank holding teachers [start, stop)."""
        ...

    def predict_teachers(self, learner, bank, X) -> jnp.ndarray:
        """Predictions of every teacher in the bank: (t, T) int32."""
        ...


class LoopEngine:
    """Serial reference engine (seed semantics of the legacy loop)."""
    name = "loop"

    def fit_teachers(self, keys, learner, datasets):
        return [learner.fit(kk, X, y)
                for kk, (X, y) in zip(keys, datasets)]

    def slice_bank(self, bank, start, stop):
        return bank[start:stop]

    def predict_teachers(self, learner, bank, X):
        return jnp.stack([learner.predict(st, X) for st in bank])


class VmapEngine:
    """Batched engine: one vmap'd fit over the stacked teacher grid.

    Learners opt in by providing ``fit_stacked(keys, Xs, ys)`` /
    ``predict_stacked(states, X)`` (see NNLearner); learners without the
    hooks (e.g. the histogram tree learners) fall back to the serial
    path with identical keys, so mixing learner kinds stays correct.
    """
    name = "vmap"

    def fit_teachers(self, keys, learner, datasets):
        if not hasattr(learner, "fit_stacked"):
            return [learner.fit(kk, X, y)
                    for kk, (X, y) in zip(keys, datasets)]
        return learner.fit_stacked(jnp.stack(list(keys)),
                                   [X for X, _ in datasets],
                                   [y for _, y in datasets])

    def slice_bank(self, bank, start, stop):
        if isinstance(bank, list):                 # serial fallback
            return bank[start:stop]
        return jax.tree.map(lambda leaf: leaf[start:stop], bank)

    def predict_teachers(self, learner, bank, X):
        if isinstance(bank, list):                 # serial fallback
            return jnp.stack([learner.predict(st, X) for st in bank])
        return learner.predict_stacked(bank, X)


_ENGINES = {"loop": LoopEngine, "vmap": VmapEngine}


def get_engine(engine) -> Engine:
    """Engine instance from a name ("loop" | "vmap") or pass-through."""
    if isinstance(engine, str):
        if engine not in _ENGINES:
            raise ValueError(f"unknown engine {engine!r}; "
                             f"available: {sorted(_ENGINES)}")
        return _ENGINES[engine]()
    return engine
