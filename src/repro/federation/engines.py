"""Execution engines: HOW a party's teachers get trained and queried.

The protocol (who sends what, once) lives in party.py / server.py /
session.py; an Engine only decides how a batch of teachers is fit and
how a trained bank predicts the public queries:

  LoopEngine : one ``learner.fit`` per teacher, serially — the seed
               semantics of the original ``run_fedkt`` loop.
  VmapEngine : stacks all given teachers into one ``jax.vmap``-ed fit
               over a shared pow2-padded bucket.  The Party hands it
               its full s*t teacher grid, so the n*s*t sequential jit
               dispatches of the serial loop collapse to one batched
               dispatch per party — the headline wall-clock win (see
               BENCH_federation_engines.json).
  LMEngine   : the sharded-LM path (core/distill.py) behind the same
               contract.  Teachers are a full ``models.Model`` each;
               a trained bank is ONE pytree with the member params
               stacked on a leading axis (the mesh "data" axis at
               datacenter scale), and the per-partition vote runs as
               the fused ``make_label_step`` — vmap'd greedy predict +
               blocked token vote, the paper's single collective round.
               Requires a learner with the LM hooks
               (``vote_members``/``predict_stacked``: core.learners.
               LMLearner).

The full written contract (method-by-method, the ``fit_stacked``
key-for-key reproduction rule, the zero-weight padding rule, and the
wire message kinds) lives in docs/engines.md.

PRNG contract: engines never split keys.  The Party precomputes the
legacy loop's exact key schedule (one split per teacher, in partition/
subset order) and passes one key per teacher, so switching engines
never changes which key a teacher sees.  When every subset pads to the
same pow2 bucket the two engines are bit-identical; otherwise they may
differ in trailing pad size and are only required to agree on vote
labels (test-enforced).

Vote contract: the party-side vote is an engine concern too
(``label_queries``), because HOW the queries get labeled is execution —
serial predicts + one histogram build, or the LM path's fused
label step.  Every engine must return the labels AND the CLEAN
(pre-noise) top1-top2 gap the Lemma-7 accountant needs, bit-identical
to serial predicts + ``core.voting.teacher_vote`` at the same key.

Kernel-backend contract: engines never pick numeric backends.  A
learner carries its own knobs (e.g. the tree learners' ``impl`` field
selecting the ``ops.tree_hist`` histogram backend) and both engines
call the same learner methods, so a backend choice can never diverge
between the serial and batched paths.
"""
from __future__ import annotations

from typing import Any, List, Protocol, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.voting import party_vote_counts, teacher_vote


class Engine(Protocol):
    """Pluggable teacher/student-execution backend."""
    name: str

    def fit_teachers(self, keys: Sequence[Any], learner,
                     datasets: Sequence[Tuple[Any, Any]]) -> Any:
        """Trains one teacher per (X, y) dataset with the paired key.
        Returns an opaque teacher bank."""
        ...

    def slice_bank(self, bank, start: int, stop: int) -> Any:
        """The sub-bank holding teachers [start, stop)."""
        ...

    def predict_teachers(self, learner, bank, X) -> jnp.ndarray:
        """Predictions of every teacher in the bank: (t, T) int32."""
        ...

    def label_queries(self, learner, bank, X, num_classes: int, *,
                      gamma: float = 0.0, key=None):
        """One partition's ensemble answers the public queries: noisy
        max-vote ``labels (T,)`` plus the CLEAN top1-top2 ``gap (T,)``
        (Lemma 7).  Must be bit-identical to serial per-teacher predicts
        + ``teacher_vote`` at the same key."""
        ...

    def fit_students(self, keys: Sequence[Any], learner, X,
                     labelsets: Sequence[Any]) -> List[Any]:
        """Trains one student per voted labelset, all on the SAME query
        set X.  Returns a plain list of student states — the PartyUpdate
        wire format — so batching is an execution detail, not a protocol
        change."""
        ...

    def predict_students(self, learner, states: Sequence[Any],
                         X) -> jnp.ndarray:
        """Predictions of a list of (unstacked) student states on one
        shared X: (len(states), T) int32."""
        ...

    def student_vote_counts(self, learner, states: Sequence[Any], X,
                            domain, *,
                            consistent: bool = True) -> jnp.ndarray:
        """ONE party's additive server-vote contribution, shaped by its
        VoteDomain: (domain.num_units, domain.num_classes) int32.  The
        streaming aggregator (federation/aggregate.py) folds these per
        arriving update, so the server never holds more than one
        party's predictions at a time.  Must equal
        ``voting.party_vote_counts(predict_students(...), domain)`` —
        the default below — but an engine may fuse predict + count into
        one dispatch."""
        ...


def _students_vote_counts(engine, learner, states, X, domain,
                          consistent):
    """Default ``student_vote_counts``: the engine's own student
    predicts, reduced by ``voting.party_vote_counts`` over the party's
    declared domain."""
    preds = engine.predict_students(learner, states, X)
    return party_vote_counts(preds, domain, consistent=consistent)


def _serial_fit_students(keys, learner, X, labelsets):
    return [learner.fit(kk, X, y) for kk, y in zip(keys, labelsets)]


def _serial_predict(learner, states, X):
    return jnp.stack([learner.predict(st, X) for st in states])


def _histogram_vote(engine, learner, bank, X, num_classes, gamma, key):
    """Default ``label_queries``: per-teacher predicts + one histogram
    build (``votes_with_clean`` under the hood)."""
    preds = engine.predict_teachers(learner, bank, X)
    vote = teacher_vote(preds, num_classes, gamma=gamma, key=key)
    return vote.labels, vote.top_gap


class LoopEngine:
    """Serial reference engine (seed semantics of the legacy loop)."""
    name = "loop"

    def fit_teachers(self, keys, learner, datasets):
        return [learner.fit(kk, X, y)
                for kk, (X, y) in zip(keys, datasets)]

    def slice_bank(self, bank, start, stop):
        return bank[start:stop]

    def predict_teachers(self, learner, bank, X):
        return _serial_predict(learner, bank, X)

    def label_queries(self, learner, bank, X, num_classes, *,
                      gamma=0.0, key=None):
        return _histogram_vote(self, learner, bank, X, num_classes,
                               gamma, key)

    def fit_students(self, keys, learner, X, labelsets):
        return _serial_fit_students(keys, learner, X, labelsets)

    def predict_students(self, learner, states, X):
        return _serial_predict(learner, states, X)

    def student_vote_counts(self, learner, states, X, domain, *,
                            consistent=True):
        return _students_vote_counts(self, learner, states, X,
                                     domain, consistent)


class VmapEngine:
    """Batched engine: one vmap'd fit over the stacked teacher grid.

    Learners opt in by providing ``fit_stacked(keys, Xs, ys)`` /
    ``predict_stacked(states, X)`` (NNLearner, RFLearner, GBDTLearner);
    learners without the hooks fall back to the serial path with
    identical keys, so mixing learner kinds stays correct.

    Students batch too: a party's s students all train on the same query
    set, so their fits share one bucket and stacking is always
    bit-identical to the serial loop (engine-agreement test-enforced).
    """
    name = "vmap"

    def fit_teachers(self, keys, learner, datasets):
        if not hasattr(learner, "fit_stacked"):
            return [learner.fit(kk, X, y)
                    for kk, (X, y) in zip(keys, datasets)]
        return learner.fit_stacked(jnp.stack(list(keys)),
                                   [X for X, _ in datasets],
                                   [y for _, y in datasets])

    def slice_bank(self, bank, start, stop):
        if isinstance(bank, list):                 # serial fallback
            return bank[start:stop]
        return jax.tree.map(lambda leaf: leaf[start:stop], bank)

    def predict_teachers(self, learner, bank, X):
        if isinstance(bank, list):                 # serial fallback
            return _serial_predict(learner, bank, X)
        return learner.predict_stacked(bank, X)

    def label_queries(self, learner, bank, X, num_classes, *,
                      gamma=0.0, key=None):
        return _histogram_vote(self, learner, bank, X, num_classes,
                               gamma, key)

    def fit_students(self, keys, learner, X, labelsets):
        if not hasattr(learner, "fit_stacked") or len(labelsets) < 2:
            return _serial_fit_students(keys, learner, X, labelsets)
        stacked = learner.fit_stacked(jnp.stack(list(keys)),
                                      [X] * len(labelsets),
                                      list(labelsets))
        return [jax.tree.map(lambda leaf: leaf[i], stacked)
                for i in range(len(labelsets))]

    def predict_students(self, learner, states, X):
        if not hasattr(learner, "predict_stacked") or len(states) < 2:
            return _serial_predict(learner, states, X)
        bank = jax.tree.map(lambda *leaves: jnp.stack(leaves), *states)
        return learner.predict_stacked(bank, X)

    def student_vote_counts(self, learner, states, X, domain, *,
                            consistent=True):
        return _students_vote_counts(self, learner, states, X,
                                     domain, consistent)


class LMEngine:
    """Sharded-LM engine: distill.py's label/train steps as the
    execution backend.

    Teacher fits are full training loops (one jitted step reused across
    fits — serial dispatch is already one jit call per step), but the
    trained bank is the distill.py layout: member params STACKED on a
    leading axis, which is what ``make_label_step`` vmaps over and what
    fedkt_dryrun shards over the production mesh's "data" axis.  The
    per-partition vote is the fused label step — greedy predict + the
    blocked token vote in one dispatch (ONE cross-member all-reduce
    under pjit: the paper's single communication round at scale).

    Requires the learner to provide the LM hooks (``vote_members``,
    ``predict_stacked`` — core.learners.LMLearner); generic learners
    should use the loop/vmap engines instead.
    """
    name = "lm"

    @staticmethod
    def _require_lm(learner):
        if not hasattr(learner, "vote_members"):
            raise TypeError(
                f"engine='lm' needs an LM learner (vote_members/"
                f"predict_stacked hooks); got {type(learner).__name__}. "
                f"Use engine='loop' or 'vmap' for generic learners.")

    def fit_teachers(self, keys, learner, datasets):
        self._require_lm(learner)
        states = [learner.fit(kk, X, y)
                  for kk, (X, y) in zip(keys, datasets)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    def slice_bank(self, bank, start, stop):
        return jax.tree.map(lambda leaf: leaf[start:stop], bank)

    def predict_teachers(self, learner, bank, X):
        return learner.predict_stacked(bank, X)

    def label_queries(self, learner, bank, X, num_classes, *,
                      gamma=0.0, key=None):
        self._require_lm(learner)
        vocab = learner.model.cfg.vocab_size
        if num_classes != vocab:
            raise ValueError(f"cfg.num_classes={num_classes} must equal "
                             f"the model vocab_size={vocab} on the LM "
                             f"path (token labels ARE class labels)")
        return learner.vote_members(bank, X, gamma=gamma, key=key)

    def fit_students(self, keys, learner, X, labelsets):
        return _serial_fit_students(keys, learner, X, labelsets)

    def predict_students(self, learner, states, X):
        return _serial_predict(learner, states, X)

    def student_vote_counts(self, learner, states, X, domain, *,
                            consistent=True):
        return _students_vote_counts(self, learner, states, X,
                                     domain, consistent)


_ENGINES = {"loop": LoopEngine, "vmap": VmapEngine, "lm": LMEngine}


def get_engine(engine) -> Engine:
    """Engine instance from a name ("loop" | "vmap" | "lm") or
    pass-through."""
    if isinstance(engine, str):
        if engine not in _ENGINES:
            raise ValueError(f"unknown engine {engine!r}; "
                             f"available: {sorted(_ENGINES)}")
        return _ENGINES[engine]()
    return engine
