"""Durable round journal: the coordinator's write-ahead log of frames.

The one-shot protocol's single round is its single point of failure: a
coordinator crash after k of n parties delivered means every silo's
teacher grid retrains.  The journal removes that cliff — each accepted
PartyUpdate's RAW codec frame is appended here, flushed, and fsync'd
BEFORE the coordinator ACKs the party or folds the update, so at every
instant the journal holds every update the protocol has acknowledged.
Because integer vote folding commutes (the PR 6 invariant the socket
path is built on), replaying the journal reconstructs the streaming
aggregate bit-identically in any order: a restarted coordinator refolds
the journaled parties and waits only for the missing ones
(federation/net.py, tests/test_faults.py).

File format (little-endian throughout):

    header  : magic b"FKTJRNL1"
    record  : uint32 party_id | uint32 crc32(frame) | uint32 nbytes
              | frame (nbytes raw codec bytes, crc trailer included)

Replay semantics (``resume=True``):

  torn tail     : a record cut short by the crash (header or frame
                  bytes missing) is TRUNCATED off the file, so later
                  appends extend the valid prefix — never interleave
                  with garbage.
  corrupt record: a structurally complete record whose frame fails its
                  crc32 is skipped and counted
                  (``corrupt_records_dropped``); its party is NOT
                  marked seen, so a fresh delivery re-journals it.
  duplicates    : the first valid record per party wins; later ones
                  are counted in ``duplicate_records_dropped`` (they
                  can only appear after a corrupt-record recovery).

Idempotent delivery rides on ``frame_matches``: a retransmitted frame
whose bytes equal the journaled ones (exact read-back comparison, not
just the crc) is the lost-ACK case — the coordinator re-ACKs it instead
of NAKing a duplicate, so a party may safely send-until-ACK.
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Dict, List, Tuple

MAGIC = b"FKTJRNL1"
_REC = struct.Struct("<III")     # party_id, crc32(frame), nbytes


class JournalError(ValueError):
    """The file is not a round journal, or an append is invalid."""


class JournalExistsError(JournalError):
    """The journal already holds records and ``resume`` was not set —
    refusing to silently fold a previous round's frames."""


class RoundJournal:
    """Append-only write-ahead journal of accepted update frames.

    ``RoundJournal(path)`` starts a FRESH round journal (the file may
    exist but must be empty or absent); ``resume=True`` additionally
    replays an existing file: ``records`` then holds the valid
    ``(party_id, frame)`` pairs in append order, the torn tail (if
    any) is truncated, and subsequent appends continue the same file.

    One writer per file.  ``append`` is called from the coordinator's
    accept loop under the round lock; it returns only after the record
    is flushed AND fsync'd — the caller may then ACK.
    """

    def __init__(self, path, *, resume: bool = False):
        self.path = str(path)
        self.records: List[Tuple[int, bytes]] = []
        self.corrupt_records_dropped = 0
        self.duplicate_records_dropped = 0
        self.truncated_tail = False
        self.resumed = False
        # party_id -> (frame offset, nbytes, crc32): the read-back
        # index for frame_matches — constant memory per party
        self._index: Dict[int, Tuple[int, int, int]] = {}
        size = os.path.getsize(self.path) \
            if os.path.exists(self.path) else 0
        if size:
            if not resume:
                raise JournalExistsError(
                    f"journal {self.path} already holds {size} bytes; "
                    f"pass resume=True (--resume) to replay it into "
                    f"this round, or remove the file to start fresh")
            self._scan(size)
            self.resumed = True
        self._f = open(self.path, "ab")
        if size == 0:
            self._f.write(MAGIC)
            self._f.flush()
            os.fsync(self._f.fileno())

    # -- replay -----------------------------------------------------------
    def _scan(self, size: int) -> None:
        """Walks the file once: validates the header, crc-checks every
        record, stops at (and truncates) a torn tail."""
        with open(self.path, "rb") as f:
            if f.read(len(MAGIC)) != MAGIC:
                raise JournalError(
                    f"{self.path} is not a FedKT round journal "
                    f"(bad magic)")
            valid_end = len(MAGIC)
            while True:
                rec = f.read(_REC.size)
                if len(rec) < _REC.size:
                    self.truncated_tail = len(rec) > 0
                    break
                pid, crc, nbytes = _REC.unpack(rec)
                frame = f.read(nbytes)
                if len(frame) < nbytes:
                    self.truncated_tail = True
                    break
                if zlib.crc32(frame) != crc:
                    self.corrupt_records_dropped += 1
                elif pid in self._index:
                    self.duplicate_records_dropped += 1
                else:
                    self._index[pid] = (valid_end + _REC.size,
                                        nbytes, crc)
                    self.records.append((pid, frame))
                valid_end += _REC.size + nbytes
        if valid_end < size:
            # torn tail: cut the file back to the last complete record
            # so this round's appends extend a clean prefix
            with open(self.path, "r+b") as f:
                f.truncate(valid_end)

    # -- writing ----------------------------------------------------------
    def append(self, party_id: int, frame: bytes) -> None:
        """Journals one accepted frame; durable (fsync) on return."""
        pid = int(party_id)
        if pid in self._index:
            raise JournalError(f"party {pid} is already journaled; "
                               f"matching retransmits are re-ACKed, "
                               f"never re-appended")
        crc = zlib.crc32(frame)
        off = self._f.tell()
        self._f.write(_REC.pack(pid, crc, len(frame)))
        self._f.write(frame)
        self._f.flush()
        os.fsync(self._f.fileno())
        self._index[pid] = (off + _REC.size, len(frame), crc)

    # -- idempotency ------------------------------------------------------
    @property
    def journaled_parties(self) -> List[int]:
        return sorted(self._index)

    def frame_matches(self, party_id: int, frame: bytes) -> bool:
        """True iff this exact frame is what the journal holds for the
        party — length and crc first (cheap), then an exact read-back
        byte comparison.  The read-back is the load-bearing step: a
        codec-v3 frame ends with the crc32 of its own body, so
        crc32(frame) is the SAME constant residue for every valid
        frame — the cheap check alone could never tell two same-length
        updates apart, and a re-ACK must never ride that."""
        ent = self._index.get(int(party_id))
        if ent is None:
            return False
        off, nbytes, crc = ent
        if len(frame) != nbytes or zlib.crc32(frame) != crc:
            return False
        with open(self.path, "rb") as f:
            f.seek(off)
            return f.read(nbytes) == frame

    def close(self) -> None:
        if self._f is not None and not self._f.closed:
            self._f.close()

    def __enter__(self) -> "RoundJournal":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False
