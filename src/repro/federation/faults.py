"""Deterministic fault injection for the socket federation.

The crash-safety layer (journal.py + the resumable coordinator in
net.py) is only trustworthy if the failure modes it claims to survive
are actually exercised.  This module makes them reproducible:

  Fault       : one scripted failure — what goes wrong on one
                party->coordinator connection, or inside the
                coordinator itself.
  FaultPlan   : connection-ordinal -> Fault, either scripted (pass the
                dict) or seeded-random (``FaultPlan.random``) so a
                chaos soak replays identically from its seed.  At most
                one coordinator-side kill rides alongside.
  ChaosProxy  : an in-path TCP proxy between party clients and the
                real coordinator.  Each inbound connection is assigned
                the next ordinal and its fault (if any) is applied to
                the bytes in flight.

Connection faults and how the stack absorbs them:

  kill_after  : the proxy forwards only the first ``at_byte`` bytes
                and closes both sides — the coordinator sees a
                truncated frame, the party sees a dead socket and
                retries (send-until-ACK).
  corrupt     : byte ``at_byte`` of the frame is flipped in flight —
                the codec's crc32 trailer catches it, the coordinator
                NAKs with reason ``corrupt`` (retryable), the party
                retransmits.
  delay       : the frame is held ``delay_s`` before forwarding —
                exercises deadline/quorum interplay.
  drop_ack    : the frame is delivered and accepted but the ACK never
                reaches the party — the party retransmits identical
                bytes and the coordinator re-ACKs them (idempotent
                delivery; never double-folded).
  duplicate   : after the normal exchange, the SAME frame is delivered
                again on a fresh connection — the coordinator must
                re-ACK without re-folding.

``kill_coordinator`` is not a proxy action: FaultPlan wires it into
the coordinator as a hook that fires AFTER the journal append and
BEFORE the ACK/fold — the exact window crash recovery must cover.  The
coordinator dies without replying; a restart with ``resume=True``
replays the journaled frame and re-ACKs the party's retransmit.

Every fault that fires is recorded in ``plan.log`` (thread-appended),
so a soak run reports what actually happened, not what was scheduled.
A retransmit rides a NEW connection with a new ordinal, so unless the
plan faults that ordinal too, the retry passes clean — every
connection fault above is recoverable by the client's retry loop.
"""
from __future__ import annotations

import random
import socket
import struct
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional

_LEN = struct.Struct("<I")

CONNECTION_FAULTS = ("kill_after", "corrupt", "delay", "drop_ack",
                     "duplicate")


@dataclass(frozen=True)
class Fault:
    """One scripted failure.

    kind    : one of CONNECTION_FAULTS.
    at_byte : kill_after — forward only this many bytes; corrupt —
              flip this byte of the frame (clamped past the 4-byte
              length prefix: mangling the framing would hang the
              reader, which is a different fault than corruption).
    delay_s : delay — seconds to hold the frame.
    """
    kind: str
    at_byte: int = 0
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in CONNECTION_FAULTS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"available: {list(CONNECTION_FAULTS)}")


class FaultPlan:
    """A seeded, scriptable failure schedule for one round.

    faults : connection ordinal (0-based, in proxy accept order) ->
             Fault.  Ordinals not named pass clean — including the
             retransmits earlier faults provoke.
    kill_coordinator_on_party : party id whose journal append kills
             the coordinator (the append->ACK/fold crash window);
             None disables.  Used by the scripted recovery tests, not
             by ``random`` — a dead coordinator ends the round rather
             than degrading it.
    """

    def __init__(self, faults: Mapping[int, Fault] = (), *,
                 kill_coordinator_on_party: Optional[int] = None):
        self.faults: Dict[int, Fault] = dict(faults or {})
        self.kill_coordinator_on_party = kill_coordinator_on_party
        self.log: List[str] = []
        self._log_lock = threading.Lock()

    @classmethod
    def random(cls, seed: int, n_connections: int, *,
               fault_rate: float = 0.25,
               max_delay_s: float = 0.2) -> "FaultPlan":
        """A reproducible chaos schedule: each of the first
        ``n_connections`` ordinals independently draws a connection
        fault with probability ``fault_rate``.  Same seed, same plan —
        a failing soak replays exactly."""
        rng = random.Random(seed)
        faults: Dict[int, Fault] = {}
        for i in range(int(n_connections)):
            if rng.random() < fault_rate:
                kind = CONNECTION_FAULTS[
                    rng.randrange(len(CONNECTION_FAULTS))]
                faults[i] = Fault(kind,
                                  at_byte=8 + rng.randrange(256),
                                  delay_s=rng.random() * max_delay_s)
        return cls(faults)

    def fault_for(self, ordinal: int) -> Optional[Fault]:
        return self.faults.get(int(ordinal))

    def record(self, msg: str) -> None:
        with self._log_lock:
            self.log.append(msg)

    def coordinator_hook(self) -> Optional[Callable[[str, int], bool]]:
        """The coordinator-side injection point: called as
        ``hook(event, party_id)`` at named protocol points; returning
        True at "journaled" kills the coordinator before it ACKs or
        folds (net.Coordinator)."""
        if self.kill_coordinator_on_party is None:
            return None
        target = int(self.kill_coordinator_on_party)

        def hook(event: str, party_id: int) -> bool:
            if event == "journaled" and int(party_id) == target:
                self.record(f"kill_coordinator: party {target} "
                            f"journaled; dying before ACK/fold")
                return True
            return False
        return hook


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def _recv_reply(sock: socket.socket) -> bytes:
    """The coordinator's reply: 1 byte (ACK) or 2 (NAK + reason)."""
    first = sock.recv(1)
    if not first:
        return b""
    rest = b""
    if first != b"\x06":
        try:
            rest = sock.recv(1)
        except OSError:
            rest = b""
    return first + rest


class ChaosProxy:
    """In-path TCP chaos proxy for party->coordinator frames.

    Listens on its own ephemeral port; each accepted connection relays
    exactly one length-prefixed frame upstream and the 1-2 byte reply
    back, with the connection's scheduled fault (``plan``) applied in
    flight.  The protocol is strictly request-reply, so the relay is
    sequential per connection — no duplex pumps, fully deterministic
    for scripted plans.
    """

    def __init__(self, upstream_host: str, upstream_port: int,
                 plan: FaultPlan, *, host: str = "127.0.0.1",
                 port: int = 0, io_timeout_s: float = 60.0):
        self.upstream = (upstream_host, int(upstream_port))
        self.plan = plan
        self.host, self._req_port = host, port
        self.io_timeout_s = io_timeout_s
        self.port: Optional[int] = None
        self.connections = 0
        self._lock = threading.Lock()
        self._stopping = False
        self._lsock: Optional[socket.socket] = None
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "ChaosProxy":
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((self.host, self._req_port))
        self._lsock.listen(128)
        self.port = self._lsock.getsockname()[1]
        self._thread = threading.Thread(target=self._accept_loop,
                                        daemon=True,
                                        name="fedkt-chaos-proxy")
        self._thread.start()
        return self

    def _accept_loop(self) -> None:
        while not self._stopping:
            try:
                conn, _ = self._lsock.accept()
            except OSError:
                return                      # listener closed: stop()
            with self._lock:
                ordinal = self.connections
                self.connections += 1
            threading.Thread(target=self._relay, args=(conn, ordinal),
                             daemon=True).start()

    def _relay(self, party: socket.socket, ordinal: int) -> None:
        fault = self.plan.fault_for(ordinal)
        try:
            party.settimeout(self.io_timeout_s)
            with party, socket.create_connection(
                    self.upstream, timeout=self.io_timeout_s) as coord:
                head = _recv_exact(party, _LEN.size)
                frame = head + _recv_exact(party,
                                           _LEN.unpack(head)[0])
                if fault is not None and fault.kind == "delay":
                    self.plan.record(f"conn {ordinal}: delay "
                                     f"{fault.delay_s:.3f}s")
                    time.sleep(fault.delay_s)
                if fault is not None and fault.kind == "kill_after":
                    cut = max(0, min(fault.at_byte, len(frame) - 1))
                    self.plan.record(f"conn {ordinal}: kill_after "
                                     f"{cut} of {len(frame)} bytes")
                    coord.sendall(frame[:cut])
                    return                  # both sides closed
                if fault is not None and fault.kind == "corrupt":
                    # clamp past the length prefix: mangled framing
                    # hangs the reader instead of testing the crc
                    k = max(_LEN.size,
                            min(fault.at_byte, len(frame) - 1))
                    self.plan.record(f"conn {ordinal}: corrupt byte "
                                     f"{k}")
                    frame = frame[:k] + bytes([frame[k] ^ 0xFF]) \
                        + frame[k + 1:]
                coord.sendall(frame)
                reply = _recv_reply(coord)
                if fault is not None and fault.kind == "drop_ack":
                    self.plan.record(f"conn {ordinal}: drop_ack "
                                     f"(swallowed {reply!r})")
                    return                  # party never sees the ACK
                if reply:
                    party.sendall(reply)
                if fault is not None and fault.kind == "duplicate":
                    # redeliver the SAME (uncorrupted) bytes on a fresh
                    # upstream connection: idempotent delivery means a
                    # re-ACK, and never a double fold
                    with socket.create_connection(
                            self.upstream,
                            timeout=self.io_timeout_s) as dup:
                        dup.sendall(frame)
                        dup_reply = _recv_reply(dup)
                    self.plan.record(f"conn {ordinal}: duplicate "
                                     f"delivery -> {dup_reply!r}")
        except OSError as err:
            self.plan.record(f"conn {ordinal}: relay ended ({err!r})")

    def stop(self) -> None:
        self._stopping = True
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
        if self._thread is not None:
            self._thread.join(timeout=5.0)

    def __enter__(self) -> "ChaosProxy":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.stop()
        return False
