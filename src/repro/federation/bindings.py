"""Per-party learner/engine bindings: heterogeneous ensembles in one
session.

FedKT's model-agnosticism claim is that ANY classification model can be
a party's learner — the vote layout is integer counts over (vote unit,
class), so a hospital's gradient-boosted trees, a bank's MLP, and a
lab's LM can ensemble in the same round.  A ``PartyBinding`` is what a
single party brings to the session: its teacher learner, its student
learner, its execution engine, and nothing else — everything
cross-party (the query set, the vote histogram, the privacy
accounting) stays session-global.

The homogeneous shorthand ``FedKTSession(learner, data, cfg,
engine=...)`` resolves to ONE binding shared by every party, so the
legacy constructor is the n-identical-bindings special case and stays
seed-for-seed identical to its pre-binding behavior (test-enforced in
tests/test_federation.py).  Heterogeneous sessions pass a sequence of
bindings instead of a learner:

    FedKTSession([PartyBinding(RFLearner(...)),
                  PartyBinding(GBDTLearner(...), engine="vmap"),
                  PartyBinding(NNLearner(...), engine="vmap")],
                 data, cfg, final_learner=NNLearner(...))

The only cross-party contract is the vote DOMAIN (federation/domain.py)
— the typed (unit, T, U, query-fingerprint) layout each binding derives
from its student learner via ``ResolvedBinding.domain()``.  Parties in
the SAME domain fold into one histogram; parties in different-unit
domains (per-example vs per-token voters) coexist with one histogram
each; a same-unit layout clash is refused at fold time with an error
naming both parties and both domains (federation/aggregate.py), so a
binding mix that cannot share a histogram fails loudly instead of
broadcasting or truncating.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.federation.domain import VoteDomain, learner_domain
from repro.federation.engines import Engine, get_engine

# Learner kind names, by class name so third-party learners can
# register without importing core.learners here (and so unpickled /
# decoded updates can be validated by name alone).  The kind a
# PartyUpdate declares on the wire is the kind of its STUDENT learner —
# that is the model the server must run to fold the party's votes.
_KIND_BY_CLASS: Dict[str, str] = {
    "NNLearner": "nn",
    "RFLearner": "rf",
    "GBDTLearner": "gbdt",
    "LMLearner": "lm",
}


def register_learner_kind(cls_name: str, kind: str) -> None:
    """Names a learner class for wire-level kind validation (a custom
    learner only needs this if it wants a kind shorter than its class
    name)."""
    _KIND_BY_CLASS[cls_name] = kind


def registered_learner_kinds() -> List[str]:
    """Every wire-level learner kind the registry knows, sorted — what
    a CLI should print when a roster names a kind it cannot build."""
    return sorted(set(_KIND_BY_CLASS.values()))


def learner_kind(learner: Any) -> str:
    """Short kind name for a learner instance ("nn" | "rf" | "gbdt" |
    "lm" | the lowercased class name for unregistered learners)."""
    name = type(learner).__name__
    return _KIND_BY_CLASS.get(name, name.lower())


@dataclass(frozen=True)
class PartyBinding:
    """What ONE party brings to a FedKT session.

    learner         : the party's teacher learner.
    student_learner : defaults to ``learner`` — the model distilled from
                      the party's teacher votes and shipped in its
                      PartyUpdate (the kind the server folds).
    engine          : "loop" | "vmap" | "lm" | an Engine instance, or
                      None to inherit the session's ``engine=`` default.
                      The engine is party-local: it drives this party's
                      teacher fits AND the server-side fold of this
                      party's student votes, so a tree party can ride
                      the vmap engine while an LM party rides "lm" in
                      the same round.
    """
    learner: Any
    student_learner: Any = None
    engine: Any = None

    def resolve(self, default_engine="loop") -> "ResolvedBinding":
        """Concrete (learner, student_learner, engine) triple; None
        fields inherit the session defaults."""
        return ResolvedBinding(
            learner=self.learner,
            student_learner=self.student_learner or self.learner,
            engine=get_engine(self.engine if self.engine is not None
                              else default_engine))


@dataclass(frozen=True)
class ResolvedBinding:
    """A PartyBinding with every default filled in (engine is an
    instance, student_learner is never None)."""
    learner: Any
    student_learner: Any
    engine: Engine

    @property
    def kind(self) -> str:
        """The wire-declared learner kind (of the student learner —
        the model the server runs)."""
        return learner_kind(self.student_learner)

    def domain(self, Xq, default_num_classes: int, *,
               fingerprint=None) -> VoteDomain:
        """The VoteDomain this party's student votes fold under — the
        typed replacement for the old first-update-fixes-layout rule:
        the binding DECLARES the layout up front, derived from the
        student learner (domain.learner_domain), so the aggregate and
        the socket coordinator can validate an arriving update before
        folding it.  ``fingerprint`` short-circuits the query-set hash
        when the caller already computed it."""
        return learner_domain(self.student_learner, Xq,
                              default_num_classes,
                              fingerprint=fingerprint)


def resolve_bindings(learner_or_bindings: Any, *, student_learner=None,
                     engine="loop", num_parties: int,
                     final_learner: Optional[Any] = None):
    """The session's binding resolution: one shared binding from the
    homogeneous shorthand, or one per party from an explicit sequence.

    Returns (bindings list, resolved final_learner).  The final learner
    defaults to the first binding's teacher learner — in a homogeneous
    session that is exactly the legacy ``final_learner or learner``
    default.
    """
    if isinstance(learner_or_bindings, (list, tuple)):
        if student_learner is not None:
            raise ValueError(
                "student_learner= is the homogeneous shorthand; with "
                "per-party bindings, set each PartyBinding's "
                "student_learner instead")
        if len(learner_or_bindings) != num_parties:
            raise ValueError(
                f"got {len(learner_or_bindings)} party bindings for "
                f"cfg.num_parties={num_parties}")
        bindings = []
        for i, b in enumerate(learner_or_bindings):
            if not isinstance(b, PartyBinding):
                raise TypeError(f"binding {i} is {type(b).__name__}, "
                                f"expected PartyBinding")
            bindings.append(b.resolve(default_engine=engine))
    else:
        if learner_or_bindings is None:
            raise ValueError("FedKTSession needs a learner or a "
                             "sequence of PartyBinding")
        shared = PartyBinding(learner_or_bindings,
                              student_learner=student_learner).resolve(
                                  default_engine=engine)
        bindings = [shared] * num_parties
    final = final_learner or bindings[0].learner
    return bindings, final
