"""VoteDomain: the vote-layout contract as a first-class object.

FedKT's single round works because every party's students answer one
shared query set and their votes fold into one integer histogram.  The
histogram's layout used to be an IMPLICIT convention — (T vote units,
U classes), fixed by whichever PartyUpdate arrived first — which is
exactly what blocked mixed per-token + per-example rounds and the
vertically-partitioned scenario.  A ``VoteDomain`` makes the contract
explicit and typed:

  unit        : what one vote row IS — "example" (tabular learners: one
                row per query example) or "token" (the LM path: one row
                per query TOKEN, the flat (N*S,) layout).
  num_units   : T — how many vote rows the query set produces in this
                unit.
  num_classes : U — the class space the votes range over (vocab size on
                the token path).
  fingerprint : content hash of the query set the units index into, so
                two parties can never silently vote on DIFFERENT Xq's
                that happen to share a shape.  None means "anonymous"
                (legacy frames, hand-built updates) and matches any
                fingerprint.
  label_names : optional class-name tag (purely descriptive; rides the
                wire, never affects identity).

Identity and compatibility:

  * Two domains with different ``unit`` are DISTINCT and COEXIST — the
    aggregate keeps one running histogram per domain, so an lm party
    and an nn party share a round instead of crashing.
  * Two domains with the same ``unit`` must agree on T, U, and
    fingerprint; a same-unit mismatch is refused with an error naming
    both parties and both domains (they claim the same kind of vote
    row, so folding them together would be silently wrong).

Derivation: a learner may declare its own domain via a
``vote_domain(Xq, default_num_classes, fingerprint=None)`` hook
(core.learners.LMLearner does — the token path); every other learner
gets the example domain with U taken from its own ``num_classes`` when
it has one, else the session default (``cfg.num_classes``).  See
docs/engines.md "Vote domains" for the custom-learner contract.

This module is imported from core/ and federation/ both, so it depends
on nothing but numpy and the standard library.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

UNITS = ("example", "token")


def fingerprint_queries(Xq) -> str:
    """Content hash of a query set: shape, dtype, and raw bytes.  Two
    parties voting on Xq's that differ in ANY element get different
    fingerprints, even at identical shapes."""
    X = np.ascontiguousarray(np.asarray(Xq))
    h = hashlib.blake2b(digest_size=8)
    h.update(repr((X.shape, X.dtype.str)).encode())
    h.update(X.tobytes())
    return h.hexdigest()


@dataclass(frozen=True)
class VoteDomain:
    """One vote-layout contract: (unit, T, U) plus the query-set
    fingerprint the units index into."""
    unit: str                      # "example" | "token"
    num_units: int                 # T — vote rows
    num_classes: int               # U — class space
    fingerprint: Optional[str] = None   # None = anonymous (legacy)
    label_names: Optional[Tuple[str, ...]] = field(default=None,
                                                   compare=False)

    def __post_init__(self):
        if self.unit not in UNITS:
            raise ValueError(f"unknown vote unit {self.unit!r}; "
                             f"expected one of {UNITS}")
        if self.num_units < 1 or self.num_classes < 1:
            raise ValueError(f"degenerate vote domain: T="
                             f"{self.num_units}, U={self.num_classes}")

    @property
    def key(self) -> Tuple[str, int, int, Optional[str]]:
        """Identity for histogram keying (label_names excluded — it is
        a descriptive tag, not part of the layout contract)."""
        return (self.unit, self.num_units, self.num_classes,
                self.fingerprint)

    @property
    def ident(self) -> str:
        """Short stable id string — sorts deterministically, keys the
        session's per-domain meta blocks."""
        fp = self.fingerprint or "anon"
        return f"{self.unit}:T{self.num_units}:U{self.num_classes}:{fp}"

    def describe(self) -> str:
        """Human-readable form for error messages."""
        fp = self.fingerprint[:8] if self.fingerprint else "anonymous"
        return (f"{self.unit}-unit domain (T={self.num_units} vote "
                f"rows x U={self.num_classes} classes, queries {fp})")

    def matches(self, other: "VoteDomain") -> bool:
        """True when ``other`` names the same layout.  An anonymous
        fingerprint (None) on EITHER side matches any fingerprint —
        legacy frames declare no query hash but are otherwise checked
        in full."""
        if (self.unit, self.num_units, self.num_classes) != \
                (other.unit, other.num_units, other.num_classes):
            return False
        return (self.fingerprint is None or other.fingerprint is None
                or self.fingerprint == other.fingerprint)

    # -- wire form --------------------------------------------------------
    def to_wire(self) -> Dict[str, Any]:
        """JSON-able header form (codec: rides next to learner_kind)."""
        d: Dict[str, Any] = {"unit": self.unit,
                             "num_units": int(self.num_units),
                             "num_classes": int(self.num_classes),
                             "fingerprint": self.fingerprint}
        if self.label_names is not None:
            d["label_names"] = list(self.label_names)
        return d

    @classmethod
    def from_wire(cls, d: Optional[Dict[str, Any]]
                  ) -> Optional["VoteDomain"]:
        """Inverse of ``to_wire``; None (absent header field — a
        legacy frame) stays None, the "undeclared" domain the aggregate
        infers from the party's binding."""
        if d is None:
            return None
        names = d.get("label_names")
        return cls(unit=d["unit"], num_units=int(d["num_units"]),
                   num_classes=int(d["num_classes"]),
                   fingerprint=d.get("fingerprint"),
                   label_names=tuple(names) if names is not None
                   else None)

    # -- inference --------------------------------------------------------
    @classmethod
    def infer_legacy(cls, contrib_shape, *,
                     unit: str = "example") -> "VoteDomain":
        """The inferred domain of a pre-domain contribution: its (T, U)
        shape under the given unit, anonymous fingerprint."""
        T, U = (int(d) for d in contrib_shape)
        return cls(unit=unit, num_units=T, num_classes=U)


def example_domain(Xq, num_classes: int, *,
                   fingerprint: Optional[str] = None,
                   label_names: Optional[Tuple[str, ...]] = None
                   ) -> VoteDomain:
    """One vote row per query example."""
    return VoteDomain(unit="example", num_units=int(len(Xq)),
                      num_classes=int(num_classes),
                      fingerprint=(fingerprint if fingerprint is not None
                                   else fingerprint_queries(Xq)),
                      label_names=label_names)


def token_domain(num_tokens: int, vocab_size: int, *,
                 fingerprint: Optional[str] = None) -> VoteDomain:
    """One vote row per query TOKEN (the LM path's flat (N*S,)
    layout).  Anonymous by default: inside a traced label step only
    static shapes exist, so the fingerprint is attached by the callers
    that hold the concrete query tokens."""
    return VoteDomain(unit="token", num_units=int(num_tokens),
                      num_classes=int(vocab_size),
                      fingerprint=fingerprint)


def learner_domain(student_learner, Xq, default_num_classes: int, *,
                   fingerprint: Optional[str] = None) -> VoteDomain:
    """The vote domain ONE party's students produce over ``Xq``.

    A learner that declares ``vote_domain(Xq, default_num_classes,
    fingerprint=None)`` owns its layout outright (LMLearner: token
    unit, T = N*S, U = vocab).  Every other learner votes one row per
    example with U from its own ``num_classes`` field when present,
    else the session default — in every shipped configuration the two
    agree, so the homogeneous paths are unchanged.

    ``fingerprint=None`` hashes Xq here; pass a precomputed hash when
    deriving many domains over one query set (the aggregate does).
    """
    if hasattr(student_learner, "vote_domain"):
        return student_learner.vote_domain(Xq, default_num_classes,
                                           fingerprint=fingerprint)
    u = getattr(student_learner, "num_classes", None)
    return example_domain(Xq, u if u is not None else default_num_classes,
                          fingerprint=fingerprint)


def check_same_unit(a: VoteDomain, b: VoteDomain, *, party_a, party_b
                    ) -> None:
    """The coexistence rule: same-unit domains must be identical.
    Raises naming both parties and both domains; different units pass
    (they fold into separate histograms)."""
    if a.unit == b.unit and not a.matches(b):
        raise ValueError(
            f"vote-domain clash: party {party_a} votes in a "
            f"{a.describe()} but party {party_b} votes in a "
            f"{b.describe()} — same vote unit, different layout; "
            f"refusing to fold them into one histogram")
