"""Wire codec for the federation protocol: pytrees <-> bytes.

A serialized message is self-describing — no pytree template on the
receiving side:

    MAGIC "FKT" | version byte | uint32 header_len | header JSON
                | payload | uint32 crc32 trailer          (v3)

The version byte is the cross-host compatibility gate: a peer speaking
a different encoding (including the pre-version b"FKT1" frames, whose
fourth byte 0x31 reads as version 49) gets a clear "codec version
mismatch" error instead of a garbage decode.  ``decode`` also validates
the frame length against the header's leaf table, so a truncated frame
raises instead of silently mis-parsing — both matter once frames cross
real sockets (federation/net.py) rather than a same-process queue.

v3 added the crc32 trailer (of every byte before it) so CORRUPTION —
a frame damaged in transit or at rest in the round journal — is caught
before any leaf is rebuilt, as a typed ``CorruptFrameError`` the socket
coordinator maps to a ``corrupt`` NAK reason the party may retry
(federation/net.py), never a stray decode exception mid-fold.  v2
frames (no trailer) still decode, so pre-CRC peers interoperate; v3
peers also demand the frame be EXACT (no trailing slack), closing the
flipped-version-byte downgrade that would otherwise skip the CRC.  The
typed errors all subclass ``CodecError`` (a ValueError):
``TruncatedFrameError`` (cut short at any stage), ``CorruptFrameError``
(CRC mismatch / unparseable header), ``VersionMismatchError``.

The header carries the tree structure (dict/list/tuple/None nesting,
leaves referenced by their checkpoint-style '/'-joined key path) plus
per-leaf shape/dtype/offset; the payload is the raw leaf bytes
concatenated in sorted-path order.  Leaf flattening is shared with
``checkpoint/checkpoint.py`` (``flatten_tree``), so the paths on the
wire are the same paths a checkpoint manifest records.

Because every header field is computable from shapes alone,
``encoded_nbytes`` prices a message exactly — header included — from a
``jax.eval_shape`` tree without materializing any array (used by
launch/fedkt_dryrun.py and benchmarks/comm_overhead.py, where the
full-size LM states never exist concretely).

Decoded leaves come back as numpy arrays (bit-identical bytes, same
shape/dtype); container types round-trip as dict/list/tuple/None.
"""
from __future__ import annotations

import json
import struct
import zlib
from typing import Any, Dict, List, Tuple

import numpy as np

from repro.checkpoint.checkpoint import _SEP, flatten_tree
from repro.federation.domain import VoteDomain
from repro.federation.messages import (PartyUpdate, TokenLabels,
                                       label_wire_bytes)

MAGIC = b"FKT"
VERSION = 3          # v2 added the version byte itself; v3 the crc32
#                      trailer (v2 frames still decode — no trailer)
_DECODABLE = (2, VERSION)
_PREFIX = MAGIC + bytes([VERSION])
_LEN = struct.Struct("<I")
_CRC = struct.Struct("<I")


class CodecError(ValueError):
    """Base for every refusal to decode a frame."""


class TruncatedFrameError(CodecError):
    """The frame was cut short — at the prefix, header, payload, or
    crc trailer."""


class CorruptFrameError(CodecError):
    """The frame is the right length but its bytes are damaged: the
    crc32 trailer does not match, or the header is unparseable."""


class VersionMismatchError(CodecError):
    """The frame speaks a codec version this peer cannot decode."""


def _np_dtype(name: str) -> np.dtype:
    """np.dtype by name, falling back to ml_dtypes for the jax extended
    float families (bfloat16, float8_*) numpy does not name natively."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _structure(tree, path: List[str]) -> Any:
    """JSON-able structure descriptor; leaves reference their
    flatten_tree path."""
    if tree is None:
        return {"t": "none"}
    if isinstance(tree, dict):
        keys = list(tree)
        for k in keys:
            if not isinstance(k, str) or _SEP in k:
                raise TypeError(f"codec requires {_SEP!r}-free string "
                                f"dict keys, got {k!r}")
        return {"t": "dict", "k": keys,
                "c": [_structure(tree[k], path + [k]) for k in keys]}
    if isinstance(tree, (list, tuple)):
        kind = "list" if isinstance(tree, list) else "tuple"
        return {"t": kind,
                "c": [_structure(v, path + [str(i)])
                      for i, v in enumerate(tree)]}
    return {"t": "leaf", "p": _SEP.join(path)}


def _header(tree, extra: Dict[str, Any] = None) -> Tuple[bytes, list]:
    """(header bytes, [(path, leaf)] in payload order)."""
    flat = flatten_tree(tree)
    # normalize bare python scalars; arrays and ShapeDtypeStructs
    # (abstract mode) already carry shape/dtype
    flat = {p: leaf if hasattr(leaf, "shape") else np.asarray(leaf)
            for p, leaf in flat.items()}
    order = sorted(flat)
    leaves, off = [], 0
    for p in order:
        leaf = flat[p]
        shape = tuple(int(d) for d in leaf.shape)
        dtype = np.dtype(leaf.dtype)
        n = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        leaves.append({"p": p, "shape": list(shape), "dtype": dtype.name,
                       "off": off, "n": n})
        off += n
    header = {"v": 1, "tree": _structure(tree, []), "leaves": leaves,
              **(extra or {})}
    return (json.dumps(header, sort_keys=True).encode("utf-8"),
            [(p, flat[p]) for p in order])


def encode(tree, extra_header: Dict[str, Any] = None) -> bytes:
    """Serializes a pytree of arrays into one self-describing buffer,
    crc32 of everything before it in the 4-byte trailer."""
    hdr, ordered = _header(tree, extra_header)
    parts = [_PREFIX, _LEN.pack(len(hdr)), hdr]
    parts += [np.ascontiguousarray(np.asarray(leaf)).tobytes()
              for _, leaf in ordered]
    body = b"".join(parts)
    return body + _CRC.pack(zlib.crc32(body))


def encoded_nbytes(tree, extra_header: Dict[str, Any] = None) -> int:
    """Exact wire size of ``encode(tree)`` — header, framing, payload,
    crc trailer — computed from leaf shapes/dtypes only.  Works on
    concrete arrays and on ShapeDtypeStructs (jax.eval_shape), so
    full-size LM messages can be priced without materializing a single
    parameter."""
    hdr, ordered = _header(tree, extra_header)
    payload = sum(int(np.prod(leaf.shape, dtype=np.int64))
                  * np.dtype(leaf.dtype).itemsize for _, leaf in ordered)
    return len(_PREFIX) + _LEN.size + len(hdr) + payload + _CRC.size


def decode(buf: bytes) -> Tuple[Any, Dict[str, Any]]:
    """Inverse of ``encode``: (pytree of numpy arrays, header dict).

    Raises a typed CodecError (a ValueError) — never mis-parses — on a
    frame that is not ours (bad magic), speaks a version this peer
    cannot decode, was cut short anywhere (prefix, header, payload,
    trailer), or fails its crc32 (corrupted in transit or at rest):
    the network and journal paths depend on damage being loud.  The
    crc is verified before any leaf is rebuilt.
    """
    if buf[:len(MAGIC)] != MAGIC:
        raise CodecError("not a federation codec buffer (bad magic)")
    if len(buf) < len(_PREFIX) + _LEN.size:
        raise TruncatedFrameError(
            f"truncated codec frame: {len(buf)} bytes is shorter than "
            f"the fixed prefix")
    version = buf[len(MAGIC)]
    if version not in _DECODABLE:
        raise VersionMismatchError(
            f"codec version mismatch: frame speaks v{version}, "
            f"this peer speaks v{VERSION} (and still decodes "
            f"v{_DECODABLE[0]}) — refusing to decode an incompatible "
            f"encoding")
    trailer = _CRC.size if version >= 3 else 0
    hlen = _LEN.unpack_from(buf, len(_PREFIX))[0]
    start = len(_PREFIX) + _LEN.size
    if len(buf) < start + hlen + trailer:
        raise TruncatedFrameError(
            f"truncated codec frame: header says {hlen} bytes but only "
            f"{len(buf) - start} follow the prefix")
    try:
        header = json.loads(buf[start:start + hlen].decode("utf-8"))
    except ValueError as err:
        raise CorruptFrameError(
            f"corrupt codec frame: header is not parseable JSON "
            f"({err}) — damaged in transit or at rest") from err
    base = start + hlen
    try:
        payload = max((leaf["off"] + leaf["n"]
                       for leaf in header["leaves"]), default=0)
    except (KeyError, TypeError) as err:
        raise CorruptFrameError(
            f"corrupt codec frame: header carries no well-formed leaf "
            f"table ({err!r})") from err
    if len(buf) < base + payload + trailer:
        raise TruncatedFrameError(
            f"truncated codec frame: payload needs {payload} bytes "
            f"(+{trailer} trailer), frame carries {len(buf) - base}")
    # frames must be EXACT (both versions): trailing slack would let a
    # flipped version byte smuggle a v3 frame past the crc as "v2"
    if len(buf) != base + payload + trailer:
        raise CorruptFrameError(
            f"corrupt codec frame: {len(buf) - base - payload - trailer} "
            f"trailing bytes beyond the "
            f"{'crc trailer' if trailer else 'payload'}")
    if trailer:
        stored = _CRC.unpack_from(buf, base + payload)[0]
        computed = zlib.crc32(memoryview(buf)[:base + payload])
        if stored != computed:
            raise CorruptFrameError(
                f"corrupt codec frame: crc32 trailer says "
                f"0x{stored:08x} but the frame hashes to "
                f"0x{computed:08x} — damaged in transit or at rest")
    arrays = {}
    for leaf in header["leaves"]:
        dtype = _np_dtype(leaf["dtype"])
        count = int(np.prod(leaf["shape"], dtype=np.int64))
        arr = np.frombuffer(buf, dtype=dtype, count=count,
                            offset=base + leaf["off"])
        arrays[leaf["p"]] = arr.reshape(leaf["shape"]).copy()

    def rebuild(spec):
        t = spec["t"]
        if t == "none":
            return None
        if t == "dict":
            return {k: rebuild(c) for k, c in zip(spec["k"], spec["c"])}
        if t == "list":
            return [rebuild(c) for c in spec["c"]]
        if t == "tuple":
            return tuple(rebuild(c) for c in spec["c"])
        return arrays[spec["p"]]

    return rebuild(header["tree"]), header


# ---------------------------------------------------------------------------
# PartyUpdate framing
# ---------------------------------------------------------------------------
def _update_tree(update: PartyUpdate):
    return {"student_states": update.student_states,
            "vote_gaps": update.vote_gaps}


def _update_extra(update: PartyUpdate) -> Dict[str, Any]:
    # learner_kind rides in the header: a heterogeneous server must
    # know WHICH learner family the decoded states belong to before it
    # can run them (bindings.learner_kind; None = undeclared).  The
    # declared VoteDomain rides next to it as plain JSON — the header
    # is extensible, so pre-domain peers at the same codec version
    # simply never set the field and decode to domain=None (the
    # inferred-legacy path in federation/aggregate.py)
    domain = update.domain
    return {"kind": "PartyUpdate", "party_id": int(update.party_id),
            "num_examples": int(update.num_examples),
            "learner_kind": update.learner_kind,
            "domain": domain.to_wire() if domain is not None else None,
            "meta": dict(update.meta)}


def encode_update(update: PartyUpdate) -> bytes:
    """The cross-process PartyUpdate message: student states AND the
    vote-gap trace in the payload, scalar fields in the header."""
    return encode(_update_tree(update), _update_extra(update))


def decode_update(buf: bytes) -> PartyUpdate:
    tree, header = decode(buf)
    if header.get("kind") != "PartyUpdate":
        raise ValueError(f"expected a PartyUpdate message, "
                         f"got kind={header.get('kind')!r}")
    return PartyUpdate(party_id=header["party_id"],
                       student_states=tree["student_states"],
                       vote_gaps=tree["vote_gaps"],
                       num_examples=header["num_examples"],
                       learner_kind=header.get("learner_kind"),
                       # absent on legacy frames -> None: the aggregate
                       # infers the binding-derived domain instead
                       domain=VoteDomain.from_wire(header.get("domain")),
                       meta=dict(header["meta"]))


def update_encoded_nbytes(update: PartyUpdate) -> int:
    """Measured wire size of one PartyUpdate (header + payload).
    Works abstractly too: build the update over ShapeDtypeStructs and
    full-size LM updates price without materializing a parameter."""
    return encoded_nbytes(_update_tree(update), _update_extra(update))


# ---------------------------------------------------------------------------
# TokenLabels framing (the vote-answer message kind)
# ---------------------------------------------------------------------------
def _labels_extra(msg: TokenLabels) -> Dict[str, Any]:
    return {"kind": "TokenLabels", "party_id": int(msg.party_id),
            "meta": dict(msg.meta)}


def encode_labels(msg: TokenLabels) -> bytes:
    """The vote-answer message: voted int32 labels ((T,) classes or
    (B, S) tokens) in the payload, scalar fields in the header."""
    return encode({"labels": msg.labels}, _labels_extra(msg))


def decode_labels(buf: bytes) -> TokenLabels:
    tree, header = decode(buf)
    if header.get("kind") != "TokenLabels":
        raise ValueError(f"expected a TokenLabels message, "
                         f"got kind={header.get('kind')!r}")
    return TokenLabels(party_id=header["party_id"], labels=tree["labels"],
                       meta=dict(header["meta"]))


def labels_encoded_nbytes(msg: TokenLabels) -> int:
    """Measured wire size of one TokenLabels message (header + payload);
    abstract-capable like ``update_encoded_nbytes``."""
    return encoded_nbytes({"labels": msg.labels}, _labels_extra(msg))


def lm_protocol_bytes(member_state, num_members: int, batch: int,
                      seq: int) -> Dict[str, int]:
    """Priced wire cost of the LM-scale one round, per member: its
    PartyUpdate-framed state upload (once) and the TokenLabels answer
    for a (batch, seq) public block.  ``member_state`` may be a
    ``jax.eval_shape`` tree — every number is the codec's exact framed
    size (header included), so what fedkt_dryrun records equals
    ``len(encode_*(...))`` of the real message bit-for-bit
    (test-enforced in tests/test_federation_lm.py)."""
    import jax

    upd = PartyUpdate(
        party_id=0, student_states=[member_state],
        vote_gaps=jax.ShapeDtypeStruct((batch * seq,), np.float32),
        num_examples=0, meta={"num_teachers": num_members})
    lbl = TokenLabels(
        party_id=0,
        labels=jax.ShapeDtypeStruct((batch, seq), np.int32))
    return {
        "members": num_members,
        "update_bytes_per_member": update_encoded_nbytes(upd),
        "update_payload_bytes_per_member": upd.wire_bytes(),
        "label_bytes": labels_encoded_nbytes(lbl),
        "label_payload_bytes": label_wire_bytes(batch * seq),
    }
