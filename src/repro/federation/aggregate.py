"""Streaming server-side aggregation: fold PartyUpdates as they arrive.

The batch server held every PartyUpdate (n parties x s student states)
before voting — fine for five subprocess silos, fatal for a fleet.
``StreamingVoteAggregate`` consumes each update the moment it arrives:
the party's students answer the query set once, their consistent-vote
contribution is ADDED into the running histogram of the party's VOTE
DOMAIN (federation/domain.py), the per-party accounting scalars are
folded, and the update is dropped.  Server memory is then constant in
the number of parties:

    one histogram (T, U) int32 PER DOMAIN (one in every legacy round)
  + per-party SCALARS (wire bytes, example counts, one L2 epsilon term)
  + (L2 only) the arriving party's gap trace, reduced to its epsilon
    contribution on the spot — Thm 4 composes parties by ``max``, so
    the running bound needs one float, not n gap traces.

Domains: each party's binding DECLARES its vote layout up front
(``ResolvedBinding.domain()`` — derived from the student learner over
this aggregate's query set), replacing the old first-update-fixes-
layout rule.  Per-token and per-example voters therefore COEXIST in a
round — one histogram each, one ``VoteResult`` each, one Thm-4/Lemma-7
epsilon fold each — while a same-unit layout clash (or an update whose
wire-declared domain contradicts its binding) is refused with an error
naming both parties and both domains.  A legacy round is the one-domain
case of the fold, bit-identical to the pre-domain aggregate.

Bit-identity: ``core.voting.party_vote_counts`` is exactly the per-party
term the batch ``consistent_vote`` sums, and integer addition commutes —
folding updates in ANY arrival order produces the same histograms,
labels, accuracy, and epsilon as the serial loop (test-enforced in
tests/test_net.py).  ``retain_students=True`` (the default) additionally
keeps the student states so RoundResult is unchanged for small
sessions; fleet-scale runs turn it off and keep only the fold.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import FedKTConfig
from repro.core import privacy as P
from repro.core.voting import VoteResult, finalize_vote
from repro.federation import codec
from repro.federation.bindings import learner_kind
from repro.federation.domain import (VoteDomain, check_same_unit,
                                     fingerprint_queries, learner_domain)
from repro.federation.messages import (LABEL_BYTES, PartyUpdate,
                                       TokenLabels)


class _DomainFold:
    """One domain's running state: its histogram, its L2 terms, and the
    parties that vote in it (first arrival kept for error messages)."""

    def __init__(self, domain: VoteDomain, first_pid: int,
                 first_kind: str):
        self.domain = domain
        self.counts = None               # (T, U) int32 running histogram
        self.l2_eps: Dict[int, float] = {}  # party_id -> Thm 3 epsilon
        self.parties: List[int] = []
        self.first = {"pid": first_pid, "kind": first_kind}


class StreamingVoteAggregate:
    """Running consistent-vote histograms + round accounting.

    One instance per round.  ``add`` may be called from the coordinator
    as each update lands (socket transport) or over a finished list
    (every other transport) — both paths are the same fold, so there is
    exactly one aggregation implementation in the codebase.

    Heterogeneity: ``bindings`` maps party_id -> ResolvedBinding, so a
    mixed-learner round folds each arriving update with THAT party's
    student learner and engine, under the vote domain the binding
    derives.  Integer count-folding commutes across learner kinds —
    the domain is the only cross-party contract, and it is enforced
    here per arrival, never broadcast or truncated.
    """

    def __init__(self, cfg: FedKTConfig, student_learner, engine, Xq, *,
                 retain_students: bool = True, bindings=None):
        self.cfg = cfg
        self.student_learner = student_learner
        self.engine = engine
        self.Xq = Xq
        self.retain_students = retain_students
        self.bindings = dict(bindings) if bindings else {}
        # one query-set hash for the whole round; every binding-derived
        # domain shares it, so deriving n domains hashes Xq once
        self._fp = fingerprint_queries(Xq)
        self._folds: Dict[Any, _DomainFold] = {}  # domain.key -> fold
        self._students: Dict[int, Any] = {}
        self._meta: Dict[int, Dict[str, Any]] = {}

    def _binding_for(self, pid: int, update: PartyUpdate):
        """(student_learner, engine, kind) for one arriving update:
        the party's own binding when the session registered one, else
        the session-wide pair.  A declared wire kind that contradicts
        the binding is a misrouted or mislabeled update — refuse it
        before running the wrong model over its states."""
        b = self.bindings.get(pid)
        lrn = b.student_learner if b is not None else self.student_learner
        eng = b.engine if b is not None else self.engine
        bound_kind = learner_kind(lrn)
        if update.learner_kind is not None \
                and update.learner_kind != bound_kind:
            raise ValueError(
                f"party {pid} declares learner kind "
                f"{update.learner_kind!r} but the session binds "
                f"{bound_kind!r} for it — refusing to fold states "
                f"under the wrong learner")
        return lrn, eng, bound_kind

    def expected_domain(self, student_learner) -> VoteDomain:
        """The binding-derived domain one party's votes fold under —
        the typed replacement for the first-update-fixes-layout rule."""
        return learner_domain(student_learner, self.Xq,
                              self.cfg.num_classes, fingerprint=self._fp)

    def _check_declared(self, pid: int, kind: str, expected: VoteDomain,
                        declared: Optional[VoteDomain]) -> None:
        """An update whose wire-declared domain contradicts what the
        party's binding derives is misconfigured — refuse it naming the
        party and BOTH domains.  None (legacy frames) skips the check;
        the binding-derived domain applies."""
        if declared is not None and not expected.matches(declared):
            raise ValueError(
                f"vote-domain mismatch: party {pid} ({kind}) declares a "
                f"{declared.describe()} on the wire, but its session "
                f"binding derives a {expected.describe()} — refusing "
                f"to fold an update that voted in a different domain")

    def _check_contrib(self, pid: int, kind: str, dom: VoteDomain,
                       contrib) -> None:
        """The contribution must have exactly the declared domain's
        (T, U) shape.  The integer fold would silently broadcast or
        crash deep in jnp otherwise — name the parties instead."""
        shape = tuple(int(d) for d in contrib.shape)
        if len(shape) != 2 or shape[1] != dom.num_classes:
            raise ValueError(
                f"party {pid} ({kind}) contributes vote counts of "
                f"shape {shape}, expected (T={dom.num_units}, "
                f"num_classes={dom.num_classes}) — the {dom.describe()}")
        if shape[0] != dom.num_units:
            nq = max(1, len(self.Xq))
            fold = self._folds.get(dom.key)
            context = (f"party {fold.first['pid']} "
                       f"({fold.first['kind']}) already votes in the "
                       f"declared domain at {dom.num_units} x "
                       f"{dom.num_classes} "
                       f"({dom.num_units // nq} unit(s)/query)"
                       if fold is not None and fold.parties else
                       f"its binding declares {dom.num_units} vote "
                       f"units ({dom.num_units // nq} unit(s)/query)")
            raise ValueError(
                f"vote-layout mismatch: party {pid} ({kind}) "
                f"contributes {shape[0]} vote units x {shape[1]} "
                f"classes ({shape[0] // nq} unit(s)/query), but "
                f"{context} — per-token and per-example voters cannot "
                f"share a histogram")

    def _fold_for(self, pid: int, kind: str, dom: VoteDomain
                  ) -> _DomainFold:
        """This domain's running fold, created on first arrival.  A new
        domain must coexist with every established one: different units
        get separate histograms, a same-unit layout clash is refused
        naming both parties and both domains (domain.check_same_unit)."""
        fold = self._folds.get(dom.key)
        if fold is None:
            for other in self._folds.values():
                check_same_unit(other.domain, dom,
                                party_a=other.first["pid"], party_b=pid)
            fold = self._folds[dom.key] = _DomainFold(dom, pid, kind)
        return fold

    # -- folding ----------------------------------------------------------
    def add(self, update: PartyUpdate) -> None:
        """Folds one party's update into its domain's running histogram
        and drops it."""
        pid = int(update.party_id)
        if pid in self._meta:
            raise ValueError(f"duplicate update from party {pid}")
        lrn, eng, kind = self._binding_for(pid, update)
        dom = self.expected_domain(lrn)
        self._check_declared(pid, kind, dom, update.domain)
        contrib = eng.student_vote_counts(
            lrn, update.student_states, self.Xq, dom,
            consistent=self.cfg.consistent_voting)
        self._check_contrib(pid, kind, dom, contrib)
        fold = self._fold_for(pid, kind, dom)
        fold.counts = contrib if fold.counts is None \
            else fold.counts + contrib
        fold.parties.append(pid)
        if self.cfg.privacy_level == "L2":
            # reduce the gap trace to its parallel-composition term now;
            # the trace itself never needs to be retained
            fold.l2_eps[pid] = P.fedkt_l2_epsilon(
                [np.asarray(update.vote_gaps)], self.cfg.gamma,
                dom.num_classes)
        if self.retain_students:
            self._students[pid] = update.student_states
        nlabels = int(update.meta["num_query_labels"])
        self._meta[pid] = {
            "learner_kind": kind,
            "domain": dom.ident,
            "num_examples": int(update.num_examples),
            "encoded_bytes": int(update.meta["encoded_bytes"]),
            "payload_bytes": int(update.wire_bytes()),
            "num_query_labels": nlabels,
            "labels_framed": codec.labels_encoded_nbytes(TokenLabels(
                party_id=pid,
                labels=jax.ShapeDtypeStruct((nlabels,), np.int32))),
        }

    # -- results ----------------------------------------------------------
    @property
    def num_parties(self) -> int:
        return len(self._meta)

    @property
    def party_ids(self) -> List[int]:
        """Arrived parties, in party-id order (the serial loop's order,
        whatever order the updates streamed in)."""
        return sorted(self._meta)

    def domains(self) -> List[VoteDomain]:
        """Every domain that received at least one update, sorted by
        identity — a DETERMINISTIC order, so multi-domain key threading
        (server.finalize) never depends on arrival order."""
        return [self._folds[k].domain for k in
                sorted(self._folds, key=lambda k: self._folds[k]
                       .domain.ident)]

    def _sole_fold(self) -> _DomainFold:
        if not self._folds:
            raise ValueError("no party updates were aggregated")
        if len(self._folds) > 1:
            raise ValueError(
                f"round holds {len(self._folds)} vote domains "
                f"({[f.domain.ident for f in self._folds.values()]}); "
                f"use the per-domain API (finalize_domain/counts_for)")
        return next(iter(self._folds.values()))

    def _fold_of(self, domain: VoteDomain) -> _DomainFold:
        fold = self._folds.get(domain.key)
        if fold is None:
            raise ValueError(f"no updates arrived in the "
                             f"{domain.describe()}")
        return fold

    @property
    def counts(self):
        """The single-domain round's running histogram (the legacy
        accessor; multi-domain rounds use ``counts_for``)."""
        return self._sole_fold().counts

    def counts_for(self, domain: VoteDomain):
        """One domain's running (T, U) histogram."""
        return self._fold_of(domain).counts

    def domain_parties(self, domain: VoteDomain) -> List[int]:
        """Parties that voted in one domain, in party-id order."""
        return sorted(self._fold_of(domain).parties)

    def primary_domain(self, final_learner) -> VoteDomain:
        """The domain the FINAL model distills from: the one the final
        learner itself would vote in (matched by unit first, then full
        layout), else — and always in a legacy round — the sole
        domain.  Deterministic: falls back to sorted-identity order."""
        doms = self.domains()
        if not doms:
            raise ValueError("no party updates were aggregated")
        if len(doms) == 1:
            return doms[0]
        want = self.expected_domain(final_learner)
        for d in doms:
            if d.key == want.key:
                return d
        for d in doms:
            if d.unit == want.unit:
                return d
        return doms[0]

    def finalize_domain(self, domain: VoteDomain, key) -> VoteResult:
        """Noise + argmax over ONE domain's finished histogram
        (FedKT-L1 when cfg says so); identical math to the batch
        ``consistent_vote``.  The result carries its domain."""
        fold = self._fold_of(domain)
        gamma = self.cfg.gamma if self.cfg.privacy_level == "L1" else 0.0
        return finalize_vote(fold.counts, fold.domain, gamma=gamma,
                             key=key)

    def finalize(self, key) -> VoteResult:
        """The single-domain round's finalize — the one-domain case of
        the per-domain fold, bit-identical to the pre-domain aggregate."""
        return self.finalize_domain(self._sole_fold().domain, key)

    def epsilon(self, vote: VoteResult) -> Optional[float]:
        """Data-dependent (eps, delta=1e-5) bound for the configured
        privacy level over ONE domain's arrived parties; None under L0.
        The vote names its domain (finalize_domain attached it); an
        anonymous vote resolves against the sole fold."""
        fold = (self._fold_of(vote.domain) if vote.domain is not None
                else self._sole_fold())
        cfg = self.cfg
        if cfg.privacy_level == "L1":
            # party-level: the trusted aggregator sees the global clean
            # histogram — which is exactly the running fold
            return P.fedkt_l1_epsilon(np.asarray(vote.counts), cfg.gamma,
                                      cfg.num_partitions,
                                      fold.domain.num_classes, exact=True)
        if cfg.privacy_level == "L2":
            # Thm 4 parallel composition: max over the per-party terms
            # folded at arrival time — per domain, so each domain's
            # bound covers exactly the parties that voted in it
            return float(max(fold.l2_eps.values()))
        return None

    def student_states(self) -> List[List[Any]]:
        """[party][partition] -> state, party-id order; empty when
        ``retain_students=False`` (the constant-memory mode)."""
        return [self._students[pid] for pid in self.party_ids] \
            if self.retain_students else []

    def student_states_for(self, domain: VoteDomain) -> Dict[int, Any]:
        """party_id -> student states, for the parties that voted in
        one domain; empty when ``retain_students=False``."""
        if not self.retain_students:
            return {}
        return {pid: self._students[pid]
                for pid in self.domain_parties(domain)}

    def wire_meta(self) -> Dict[str, Any]:
        """The session's wire_bytes block, summed over arrived parties
        (order-independent integer sums — identical to the batch path).
        ``per_party`` breaks the measured framed bytes down by party id,
        ``by_learner_kind`` by model family, and ``by_domain`` by vote
        domain — in a heterogeneous or mixed-domain round the families
        ship very differently-sized states, and all three views are
        needed to price a mixed fleet."""
        rows = self._meta
        by_kind: Dict[str, int] = {}
        by_domain: Dict[str, int] = {}
        for r in rows.values():
            k = r["learner_kind"]
            by_kind[k] = by_kind.get(k, 0) + r["encoded_bytes"]
            d = r["domain"]
            by_domain[d] = by_domain.get(d, 0) + r["encoded_bytes"]
        return {
            "updates": sum(r["encoded_bytes"] for r in rows.values()),
            "updates_payload": sum(r["payload_bytes"]
                                   for r in rows.values()),
            "labels": sum(r["num_query_labels"]
                          for r in rows.values()) * LABEL_BYTES,
            "labels_framed": sum(r["labels_framed"]
                                 for r in rows.values()),
            "per_party": {pid: rows[pid]["encoded_bytes"]
                          for pid in sorted(rows)},
            "by_learner_kind": by_kind,
            "by_domain": by_domain,
        }

    def party_meta(self) -> Dict[int, Dict[str, Any]]:
        """Per-party accounting scalars, keyed by party id."""
        return {pid: dict(row) for pid, row in self._meta.items()}
