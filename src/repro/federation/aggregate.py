"""Streaming server-side aggregation: fold PartyUpdates as they arrive.

The batch server held every PartyUpdate (n parties x s student states)
before voting — fine for five subprocess silos, fatal for a fleet.
``StreamingVoteAggregate`` consumes each update the moment it arrives:
the party's students answer the query set once, their consistent-vote
contribution is ADDED into one running (T, U) histogram, the per-party
accounting scalars are folded, and the update is dropped.  Server
memory is then constant in the number of parties:

    histogram (T, U) int32
  + per-party SCALARS (wire bytes, example counts, one L2 epsilon term)
  + (L2 only) the arriving party's gap trace, reduced to its epsilon
    contribution on the spot — Thm 4 composes parties by ``max``, so
    the running bound needs one float, not n gap traces.

Bit-identity: ``core.voting.party_vote_counts`` is exactly the per-party
term the batch ``consistent_vote`` sums, and integer addition commutes —
folding updates in ANY arrival order produces the same histogram,
labels, accuracy, and epsilon as the serial loop (test-enforced in
tests/test_net.py).  ``retain_students=True`` (the default) additionally
keeps the student states so RoundResult is unchanged for small
sessions; fleet-scale runs turn it off and keep only the fold.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import FedKTConfig
from repro.core import privacy as P
from repro.core.voting import VoteResult, finalize_vote
from repro.federation import codec
from repro.federation.bindings import learner_kind
from repro.federation.messages import (LABEL_BYTES, PartyUpdate,
                                       TokenLabels)


class StreamingVoteAggregate:
    """Running consistent-vote histogram + round accounting.

    One instance per round.  ``add`` may be called from the coordinator
    as each update lands (socket transport) or over a finished list
    (every other transport) — both paths are the same fold, so there is
    exactly one aggregation implementation in the codebase.

    Heterogeneity: ``bindings`` maps party_id -> ResolvedBinding, so a
    mixed-learner round folds each arriving update with THAT party's
    student learner and engine.  Integer count-folding commutes across
    learner kinds — the (T, U) vote layout is the only cross-party
    contract, and it is enforced here: the first folded update fixes
    the layout, and any later update whose vote-unit count T (per
    example vs per token) or class count U disagrees is refused with an
    error naming both parties, never broadcast or truncated.
    """

    def __init__(self, cfg: FedKTConfig, student_learner, engine, Xq, *,
                 retain_students: bool = True, bindings=None):
        self.cfg = cfg
        self.student_learner = student_learner
        self.engine = engine
        self.Xq = Xq
        self.retain_students = retain_students
        self.bindings = dict(bindings) if bindings else {}
        self.counts = None                  # (T, U) int32 running histogram
        self._layout = None                 # (T, U) fixed by first update
        self._layout_party: Dict[str, Any] = {}  # who fixed it, and how
        self._l2_eps: Dict[int, float] = {}   # party_id -> Thm 3 epsilon
        self._students: Dict[int, Any] = {}
        self._meta: Dict[int, Dict[str, Any]] = {}

    def _binding_for(self, pid: int, update: PartyUpdate):
        """(student_learner, engine, kind) for one arriving update:
        the party's own binding when the session registered one, else
        the session-wide pair.  A declared wire kind that contradicts
        the binding is a misrouted or mislabeled update — refuse it
        before running the wrong model over its states."""
        b = self.bindings.get(pid)
        lrn = b.student_learner if b is not None else self.student_learner
        eng = b.engine if b is not None else self.engine
        bound_kind = learner_kind(lrn)
        if update.learner_kind is not None \
                and update.learner_kind != bound_kind:
            raise ValueError(
                f"party {pid} declares learner kind "
                f"{update.learner_kind!r} but the session binds "
                f"{bound_kind!r} for it — refusing to fold states "
                f"under the wrong learner")
        return lrn, eng, bound_kind

    def _check_layout(self, pid: int, kind: str, contrib) -> None:
        """The cross-party vote contract: every party's contribution
        must match the (T, U) layout the first arrival fixed.  T
        differs when parties vote in different units (U vote units per
        example for tabular learners vs per TOKEN for LMs); U differs
        when class spaces disagree.  Either way the integer fold would
        silently broadcast or crash deep in jnp — name both parties
        instead."""
        shape = tuple(int(d) for d in contrib.shape)
        if len(shape) != 2 or shape[1] != self.cfg.num_classes:
            raise ValueError(
                f"party {pid} ({kind}) contributes vote counts of "
                f"shape {shape}, expected (T, num_classes="
                f"{self.cfg.num_classes})")
        if self._layout is None:
            self._layout = shape
            self._layout_party = {"pid": pid, "kind": kind}
            return
        if shape != self._layout:
            first = self._layout_party
            nq = max(1, len(self.Xq))
            raise ValueError(
                f"vote-layout mismatch: party {pid} ({kind}) "
                f"contributes {shape[0]} vote units x {shape[1]} "
                f"classes ({shape[0] // nq} unit(s)/query), but party "
                f"{first['pid']} ({first['kind']}) fixed the round "
                f"layout at {self._layout[0]} x {self._layout[1]} "
                f"({self._layout[0] // nq} unit(s)/query) — per-token "
                f"and per-example voters cannot share a histogram")

    # -- folding ----------------------------------------------------------
    def add(self, update: PartyUpdate) -> None:
        """Folds one party's update into the aggregate and drops it."""
        pid = int(update.party_id)
        if pid in self._meta:
            raise ValueError(f"duplicate update from party {pid}")
        lrn, eng, kind = self._binding_for(pid, update)
        contrib = eng.student_vote_counts(
            lrn, update.student_states, self.Xq,
            self.cfg.num_classes, consistent=self.cfg.consistent_voting)
        self._check_layout(pid, kind, contrib)
        self.counts = contrib if self.counts is None \
            else self.counts + contrib
        if self.cfg.privacy_level == "L2":
            # reduce the gap trace to its parallel-composition term now;
            # the trace itself never needs to be retained
            self._l2_eps[pid] = P.fedkt_l2_epsilon(
                [np.asarray(update.vote_gaps)], self.cfg.gamma,
                self.cfg.num_classes)
        if self.retain_students:
            self._students[pid] = update.student_states
        nlabels = int(update.meta["num_query_labels"])
        self._meta[pid] = {
            "learner_kind": kind,
            "num_examples": int(update.num_examples),
            "encoded_bytes": int(update.meta["encoded_bytes"]),
            "payload_bytes": int(update.wire_bytes()),
            "num_query_labels": nlabels,
            "labels_framed": codec.labels_encoded_nbytes(TokenLabels(
                party_id=pid,
                labels=jax.ShapeDtypeStruct((nlabels,), np.int32))),
        }

    # -- results ----------------------------------------------------------
    @property
    def num_parties(self) -> int:
        return len(self._meta)

    @property
    def party_ids(self) -> List[int]:
        """Arrived parties, in party-id order (the serial loop's order,
        whatever order the updates streamed in)."""
        return sorted(self._meta)

    def finalize(self, key) -> VoteResult:
        """Noise + argmax over the finished histogram (FedKT-L1 when
        cfg says so); identical math to the batch ``consistent_vote``."""
        if self.counts is None:
            raise ValueError("no party updates were aggregated")
        gamma = self.cfg.gamma if self.cfg.privacy_level == "L1" else 0.0
        return finalize_vote(self.counts, gamma=gamma, key=key)

    def epsilon(self, vote: VoteResult) -> Optional[float]:
        """Data-dependent (eps, delta=1e-5) bound for the configured
        privacy level over the ARRIVED parties; None under L0."""
        cfg = self.cfg
        if cfg.privacy_level == "L1":
            # party-level: the trusted aggregator sees the global clean
            # histogram — which is exactly the running fold
            return P.fedkt_l1_epsilon(np.asarray(vote.counts), cfg.gamma,
                                      cfg.num_partitions, cfg.num_classes,
                                      exact=True)
        if cfg.privacy_level == "L2":
            # Thm 4 parallel composition: max over the per-party terms
            # folded at arrival time
            return float(max(self._l2_eps.values()))
        return None

    def student_states(self) -> List[List[Any]]:
        """[party][partition] -> state, party-id order; empty when
        ``retain_students=False`` (the constant-memory mode)."""
        return [self._students[pid] for pid in self.party_ids] \
            if self.retain_students else []

    def wire_meta(self) -> Dict[str, Any]:
        """The session's wire_bytes block, summed over arrived parties
        (order-independent integer sums — identical to the batch path).
        ``per_party`` breaks the measured framed bytes down by party id
        and ``by_learner_kind`` by model family — in a heterogeneous
        round the families ship very differently-sized states, and both
        views are needed to price a mixed fleet."""
        rows = self._meta
        by_kind: Dict[str, int] = {}
        for r in rows.values():
            k = r["learner_kind"]
            by_kind[k] = by_kind.get(k, 0) + r["encoded_bytes"]
        return {
            "updates": sum(r["encoded_bytes"] for r in rows.values()),
            "updates_payload": sum(r["payload_bytes"]
                                   for r in rows.values()),
            "labels": sum(r["num_query_labels"]
                          for r in rows.values()) * LABEL_BYTES,
            "labels_framed": sum(r["labels_framed"]
                                 for r in rows.values()),
            "per_party": {pid: rows[pid]["encoded_bytes"]
                          for pid in sorted(rows)},
            "by_learner_kind": by_kind,
        }

    def party_meta(self) -> Dict[int, Dict[str, Any]]:
        """Per-party accounting scalars, keyed by party id."""
        return {pid: dict(row) for pid, row in self._meta.items()}
