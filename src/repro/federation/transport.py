"""Transports: HOW a PartyUpdate crosses the party/server boundary.

The protocol says each party sends ONE message; a Transport decides
where the party side runs and how the message travels.  Every
implementation routes the update through the wire codec — encode on the
party side, decode on the server side — so serialization sits on the
hot path in ALL modes and ``meta["encoded_bytes"]`` is the measured
(not estimated) wire size of each update:

  InProcessTransport : parties run serially in the caller's process
                       (the reference semantics; codec round-trip only).
  ThreadTransport    : parties fan out over a thread pool.  JAX dispatch
                       is thread-safe and the jitted fits release the
                       GIL, so independent parties overlap on CPU.
  SubprocessTransport: each party's local round runs in its OWN worker
                       process (spawned interpreters); the encoded
                       PartyUpdate bytes are literally what crosses the
                       process boundary — the paper's cross-silo
                       deployment shape, one process per silo.
  SocketTransport    : federation/net.py — updates cross REAL TCP
                       connections, streamed into the server's running
                       vote aggregate with deadline/quorum straggler
                       semantics.  The only transport with a
                       ``stream_round`` (``streams = True``).

Every transport is a context manager, and a party failure mid-round
must never leak execution resources: the subprocess pool is TERMINATED
(not drained) when a party raises, so no spawned interpreter outlives
the round it was serving (regression-tested in tests/test_transport.py).

Seed contract: parties receive PRECOMPUTED keys (the serial schedule
played forward by the session), so fan-out order never changes any
party's randomness and every transport is bit-identical to the
in-process loop at a fixed seed (test-enforced in
tests/test_transport.py).
"""
from __future__ import annotations

import pickle
from concurrent.futures import ThreadPoolExecutor
from typing import Any, List, Optional, Protocol, Sequence

import numpy as np

from repro.federation.codec import decode_update, encode_update
from repro.federation.messages import PartyUpdate


class Transport(Protocol):
    """Pluggable party-execution + message-passing backend."""
    name: str

    def run_round(self, parties: Sequence[Any], keys: Sequence[Any],
                  X_public, num_queries: int,
                  engine) -> List[PartyUpdate]:
        """Runs every party's local round (one precomputed key each) and
        returns the DECODED updates, in party order.  Each update's
        ``meta["encoded_bytes"]`` records its measured wire size.
        ``engine=None`` lets every party run under its OWN bound engine
        (the heterogeneous session path); an explicit engine overrides
        all bindings."""
        ...

    def close(self) -> None:
        """Releases any resources the transport holds across rounds.
        Idempotent; per-round resources must already be cleaned up by
        ``run_round`` itself (even when a party raises)."""
        ...


class TransportBase:
    """Context-manager plumbing shared by every transport: ``close`` is
    idempotent and guaranteed on ``with`` exit, success or failure.
    Per-ROUND resources (pools, sockets) are the run methods' own
    responsibility — they clean up in ``finally`` so a crashing party
    can never leak workers, with or without the ``with``."""

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False


def _decode_annotated(buf: bytes) -> PartyUpdate:
    upd = decode_update(buf)
    upd.meta["encoded_bytes"] = len(buf)
    return upd


def _encoded_round(party, key, X_public, num_queries, engine) -> bytes:
    upd, _ = party.local_round(key, X_public, num_queries, engine)
    return encode_update(upd)


class InProcessTransport(TransportBase):
    """Serial in-process reference: today's semantics plus the codec
    round-trip, so in-process and cross-process servers see byte-wise
    identical updates."""
    name = "inprocess"

    def __init__(self, parallelism: Optional[int] = None):
        if parallelism not in (None, 1):
            raise ValueError("the inprocess transport is serial; use "
                             "transport=\"thread\" or \"subprocess\" "
                             "for parallelism > 1")
        self.parallelism = 1

    def run_round(self, parties, keys, X_public, num_queries, engine):
        return [_decode_annotated(
                    _encoded_round(p, k, X_public, num_queries, engine))
                for p, k in zip(parties, keys)]


class ThreadTransport(TransportBase):
    """Concurrent parties in one interpreter.  Engines and learners are
    stateless (jit caches are internally synchronized), so sharing them
    across workers is safe; results are collected in party order."""
    name = "thread"

    def __init__(self, parallelism: Optional[int] = None):
        self.parallelism = parallelism

    def run_round(self, parties, keys, X_public, num_queries, engine):
        workers = self.parallelism or len(parties)
        ex = ThreadPoolExecutor(max_workers=workers)
        try:
            futs = [ex.submit(_encoded_round, p, k, X_public,
                              num_queries, engine)
                    for p, k in zip(parties, keys)]
            return [_decode_annotated(f.result()) for f in futs]
        finally:
            # a failed party must not make the round run the REMAINING
            # parties to completion before raising: drop queued work
            # (running threads finish their current party and exit)
            ex.shutdown(wait=False, cancel_futures=True)


def _subprocess_worker(blob: bytes) -> bytes:
    """Runs in a spawned interpreter: unpickle the silo, run its local
    round, return the codec-encoded PartyUpdate."""
    party, key, X_public, num_queries, engine = pickle.loads(blob)
    return _encoded_round(party, key, X_public, num_queries, engine)


class SubprocessTransport(TransportBase):
    """One worker process per party (spawn start method: safe after the
    parent has initialized JAX).  Workers re-import and re-jit, so cold
    cost is high — this transport exists to make the cross-silo
    deployment real, not to win single-host benchmarks.

    Cleanup contract: when any party raises, the whole worker pool is
    terminated on the spot — the old executor-based round left the
    remaining interpreters running (and kept training dropped parties)
    until their queues drained."""
    name = "subprocess"

    def __init__(self, parallelism: Optional[int] = None):
        self.parallelism = parallelism

    def run_round(self, parties, keys, X_public, num_queries, engine):
        import multiprocessing
        workers = self.parallelism or len(parties)
        Xpub = np.asarray(X_public)
        blobs = [pickle.dumps((p, np.asarray(k), Xpub, num_queries,
                               engine))
                 for p, k in zip(parties, keys)]
        ctx = multiprocessing.get_context("spawn")
        pool = ctx.Pool(processes=workers)
        done = False
        try:
            encoded = pool.map(_subprocess_worker, blobs)
            pool.close()
            pool.join()
            done = True
            return [_decode_annotated(b) for b in encoded]
        finally:
            if not done:
                # a party failed: kill every worker interpreter NOW
                # instead of letting them finish (or start) the other
                # parties' rounds
                pool.terminate()
                pool.join()


_TRANSPORTS = {"inprocess": InProcessTransport, "thread": ThreadTransport,
               "subprocess": SubprocessTransport}


def get_transport(transport, parallelism: Optional[int] = None) -> Transport:
    """Transport instance from a name ("inprocess" | "thread" |
    "subprocess" | "socket") or pass-through of an instance."""
    if isinstance(transport, str):
        if transport == "socket":
            # net.py imports this module; resolve lazily to avoid the
            # cycle while keeping one registry entry point
            from repro.federation.net import SocketTransport
            return SocketTransport(parallelism=parallelism)
        if transport not in _TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r}; "
                             f"available: "
                             f"{sorted([*_TRANSPORTS, 'socket'])}")
        return _TRANSPORTS[transport](parallelism=parallelism)
    if parallelism is not None:
        raise ValueError("parallelism= only applies when the transport "
                         "is given by name")
    return transport
