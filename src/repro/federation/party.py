"""Party: the data-holder side of the FedKT protocol (Algorithm 1
lines 2-12).

A party never shares raw examples or teacher models.  Its entire
contribution to the round is one PartyUpdate: s student models, each
distilled from a t-teacher ensemble vote on the public queries, plus
(under L2) the vote-gap trace its local accountant needs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List

import jax
import numpy as np

from repro.configs.base import FedKTConfig
from repro.core.partition import subsets_of_partition
from repro.federation.bindings import learner_kind
from repro.federation.engines import Engine, get_engine
from repro.federation.messages import LABEL_BYTES, PartyUpdate


@dataclass
class Party:
    """One silo.  ``indices`` selects its local shard of the (conceptually
    party-private) training arrays; in a deployed setting X/y would be
    the silo's own storage and ``indices`` the identity.

    The learner/student_learner/engine triple is the party's BINDING
    (federation/bindings.py): each silo brings its own model family and
    execution engine to the round, so one session can ensemble rf, gbdt,
    nn, and lm parties.  ``engine`` may be None — ``local_round`` then
    needs an explicit engine argument (the pre-binding calling
    convention, kept for the transports and direct callers)."""
    party_id: int
    X: np.ndarray
    y: np.ndarray
    indices: np.ndarray
    cfg: FedKTConfig
    learner: Any
    student_learner: Any
    engine: Any = None

    @property
    def num_examples(self) -> int:
        return len(self.indices)

    def _key_schedule(self, key, s: int, t: int):
        """The legacy loop's exact split order: per partition j, t
        teacher keys, then one vote key, then one student key.  Played
        forward here so engines get explicit keys and can batch the
        whole s*t teacher grid without changing any teacher's seed."""
        teacher_keys, vote_keys, student_keys = [], [], []
        for _ in range(s):
            for _ in range(t):
                key, kk = jax.random.split(key)
                teacher_keys.append(kk)
            key, kk = jax.random.split(key)
            vote_keys.append(kk)
            key, kk = jax.random.split(key)
            student_keys.append(kk)
        return teacher_keys, vote_keys, student_keys, key

    def advance_key(self, key):
        """The key ``local_round`` would return, WITHOUT training: the
        schedule consumes a fixed split count (s * (t + 2)), so the
        session can precompute every party's starting key and fan the
        parties out in parallel with unchanged serial-loop seeds."""
        cfg = self.cfg
        return self._key_schedule(key, cfg.num_partitions,
                                  cfg.num_subsets)[3]

    def local_round(self, key, X_public, num_queries: int,
                    engine: Engine = None):
        """Runs the party side of the single round.

        Returns (PartyUpdate, advanced key).  Key threading matches the
        legacy ``run_fedkt`` loop split-for-split, so results are
        seed-for-seed reproducible across API versions and engines.

        ``engine=None`` uses the party's OWN bound engine — the
        heterogeneous path, where each silo's binding decides how its
        teachers train; an explicit engine overrides the binding (the
        transports pass None so every party runs its own).
        """
        cfg = self.cfg
        if engine is None:
            if self.engine is None:
                raise ValueError(
                    f"party {self.party_id} has no bound engine; pass "
                    f"engine= to local_round or bind one at construction")
            engine = self.engine
        engine = get_engine(engine)
        # the party's declared VoteDomain: the layout its STUDENTS vote
        # in at the server, over the SERVER-side query slice (under
        # L1/L2 the party answers tq_party queries but its students are
        # folded over tq_server), fingerprinted so two parties cannot
        # silently vote on different query sets.  Lazy imports: session
        # imports party, and domain derivation is only needed here.
        from repro.federation.domain import (fingerprint_queries,
                                             learner_domain)
        from repro.federation.session import query_budget
        _, tq_server = query_budget(cfg, len(X_public))
        Xq_server = X_public[:tq_server]
        dom = learner_domain(self.student_learner, Xq_server,
                             cfg.num_classes,
                             fingerprint=fingerprint_queries(Xq_server))
        s, t, u = cfg.num_partitions, cfg.num_subsets, dom.num_classes
        Xq = X_public[:num_queries]
        plan = subsets_of_partition(self.indices, s, t,
                                    seed=cfg.seed + 17 * self.party_id)
        gamma = cfg.gamma if cfg.privacy_level == "L2" else 0.0

        teacher_keys, vote_keys, student_keys, key = \
            self._key_schedule(key, s, t)
        datasets = [(self.X[sub], self.y[sub])
                    for j in range(s) for sub in plan[j]]
        bank = engine.fit_teachers(teacher_keys, self.learner, datasets)

        labelsets: List[np.ndarray] = []
        gaps: List[np.ndarray] = []
        for j in range(s):
            bank_j = engine.slice_bank(bank, j * t, (j + 1) * t)
            # HOW the queries get labeled is the engine's concern
            # (serial predicts + histogram vote, or the LM path's fused
            # label step); the protocol only needs labels + clean gaps
            labels, gap = engine.label_queries(
                self.learner, bank_j, Xq, u, gamma=gamma,
                key=vote_keys[j])
            gaps.append(np.asarray(gap))
            labelsets.append(np.asarray(labels))
        # all s students vote on the same Xq, so the engine may train
        # them as ONE stacked fit; student_keys is the precomputed legacy
        # schedule, so batching never changes a student's seed
        students: List[Any] = engine.fit_students(
            student_keys, self.student_learner, Xq, labelsets)

        update = PartyUpdate(party_id=self.party_id,
                             student_states=students,
                             vote_gaps=np.concatenate(gaps),
                             num_examples=self.num_examples,
                             # the STUDENT family: what the server must
                             # run to fold this party's votes
                             learner_kind=learner_kind(
                                 self.student_learner),
                             # the declared vote layout, validated at
                             # ACK time (net.py) and at fold time
                             # (aggregate.py)
                             domain=dom,
                             meta={"num_teachers": s * t,
                                   # label answers are one vote unit per
                                   # LABEL (= per token on the LM path,
                                   # not per query sequence) — the
                                   # session's wire accounting reads this
                                   "num_query_labels": int(
                                       labelsets[0].size),
                                   "label_payload_bytes": int(
                                       labelsets[0].size * LABEL_BYTES)})
        return update, key
