"""Server: the aggregator side of the FedKT protocol (Algorithm 1
lines 13-23).

Folds the arriving PartyUpdates into a ``StreamingVoteAggregate``
(federation/aggregate.py) — one running consistent-vote histogram,
constant memory in the party count — then noises, argmaxes, and
distills the final model from the voted labels.  Being the only place
that sees the global vote histogram, the server side owns the L1
privacy accounting; L2 accounting composes the parties' local gap
traces (Thm 4 parallel composition), folded per arrival.  The batch
``aggregate`` entry point and the socket transport's streaming path are
the SAME fold, so they cannot diverge.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np

from repro.configs.base import FedKTConfig
from repro.federation.aggregate import StreamingVoteAggregate
from repro.federation.engines import Engine, LoopEngine
from repro.federation.messages import PartyUpdate


class Server:
    def __init__(self, cfg: FedKTConfig, student_learner, final_learner,
                 *, bindings=None):
        """``bindings`` (party_id -> ResolvedBinding) is the
        heterogeneous contract: the fold runs each arriving update's
        states under THAT party's student learner and engine.  Without
        it, the session-wide (student_learner, engine) pair applies to
        every party — the homogeneous shorthand."""
        self.cfg = cfg
        self.student_learner = student_learner
        self.final_learner = final_learner
        self.bindings = bindings

    def make_aggregate(self, X_public, num_queries: int,
                       engine: Engine = None, *,
                       retain_students: bool = True
                       ) -> StreamingVoteAggregate:
        """A fresh per-round fold.  ``engine`` decides how each party's
        s student models answer the query set (serial loop vs one
        stacked predict); defaults to the serial reference engine.
        Per-party bindings, when registered, override both the learner
        and the engine for their party's updates."""
        return StreamingVoteAggregate(
            self.cfg, self.student_learner, engine or LoopEngine(),
            X_public[:num_queries], retain_students=retain_students,
            bindings=self.bindings)

    def finalize(self, key, agg: StreamingVoteAggregate):
        """Vote over the finished histogram + final distillation, for a
        SINGLE-domain round (the legacy entry point; multi-domain rounds
        use ``finalize_all``).  Returns (final_state, VoteResult, key) —
        key threading matches the legacy loop split-for-split (one split
        for vote noise, one for the final fit)."""
        key, kk = jax.random.split(key)
        vote = agg.finalize(kk)
        key, kk = jax.random.split(key)
        final_state = self.final_learner.fit(kk, agg.Xq,
                                             np.asarray(vote.labels))
        return final_state, vote, key

    def finalize_all(self, key, agg: StreamingVoteAggregate):
        """Per-domain finalize: every domain that received votes gets
        its own noise split and its own VoteResult, in sorted-identity
        order (deterministic whatever order the updates streamed in);
        the final model distills from the PRIMARY domain — the one the
        final learner itself votes in (agg.primary_domain).

        Returns (final_state, primary VoteResult, {domain.ident ->
        VoteResult}, key).  With one domain this is split-for-split the
        legacy ``finalize`` — one split for vote noise, one for the
        final fit — so every existing single-domain round stays
        bit-identical."""
        votes = {}
        for dom in agg.domains():
            key, kk = jax.random.split(key)
            votes[dom.ident] = agg.finalize_domain(dom, kk)
        primary = agg.primary_domain(self.final_learner)
        vote = votes[primary.ident]
        key, kk = jax.random.split(key)
        final_state = self.final_learner.fit(kk, agg.Xq,
                                             np.asarray(vote.labels))
        return final_state, vote, votes, key

    def aggregate(self, key, updates: Sequence[PartyUpdate], X_public,
                  num_queries: int, engine: Engine = None):
        """Batch entry point: fold a finished update list, then
        finalize.  Bit-identical to the streaming path in any order."""
        agg = self.make_aggregate(X_public, num_queries, engine)
        for upd in updates:
            agg.add(upd)
        return self.finalize(key, agg)

    def epsilon(self, vote, agg: StreamingVoteAggregate) -> Optional[float]:
        """Data-dependent (eps, delta=1e-5) bound for the configured
        privacy level; None under L0.  Delegates to the aggregate, which
        folded the per-party L2 terms at arrival time."""
        return agg.epsilon(vote)
