"""Server: the aggregator side of the FedKT protocol (Algorithm 1
lines 13-23).

Collects the n PartyUpdates, runs the consistent vote over the n*s
student models, distills the final model from the voted labels, and —
being the only place that sees the global vote histogram — owns the
L1 privacy accounting.  L2 accounting composes the parties' local gap
traces (Thm 4 parallel composition).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedKTConfig
from repro.core import privacy as P
from repro.core.voting import VoteResult, consistent_vote
from repro.federation.engines import Engine, LoopEngine
from repro.federation.messages import PartyUpdate


class Server:
    def __init__(self, cfg: FedKTConfig, student_learner, final_learner):
        self.cfg = cfg
        self.student_learner = student_learner
        self.final_learner = final_learner

    def aggregate(self, key, updates: Sequence[PartyUpdate], X_public,
                  num_queries: int, engine: Engine = None):
        """Consistent vote over all student models + final distillation.

        ``engine`` decides how the n*s student models answer the query
        set (serial loop vs one stacked predict); defaults to the serial
        reference engine.  Returns (final_state, VoteResult, key).
        """
        cfg = self.cfg
        engine = engine or LoopEngine()
        Xq = X_public[:num_queries]
        student_preds = jnp.stack([
            engine.predict_students(self.student_learner,
                                    upd.student_states, Xq)
            for upd in updates])                      # (n, s, Tq)
        key, kk = jax.random.split(key)
        gamma = cfg.gamma if cfg.privacy_level == "L1" else 0.0
        vote = consistent_vote(student_preds, cfg.num_classes,
                               consistent=cfg.consistent_voting,
                               gamma=gamma, key=kk)
        key, kk = jax.random.split(key)
        final_state = self.final_learner.fit(kk, Xq,
                                             np.asarray(vote.labels))
        return final_state, vote, key

    def epsilon(self, vote: VoteResult,
                updates: Sequence[PartyUpdate]) -> Optional[float]:
        """Data-dependent (eps, delta=1e-5) bound for the configured
        privacy level; None under L0."""
        cfg = self.cfg
        if cfg.privacy_level == "L1":
            # party-level: consistent voting moves counts in multiples
            # of s, so the accountant works on the raw histogram with
            # sensitivity 2s (privacy.py Thm 1+2)
            return P.fedkt_l1_epsilon(np.asarray(vote.counts), cfg.gamma,
                                      cfg.num_partitions, cfg.num_classes,
                                      exact=True)
        if cfg.privacy_level == "L2":
            return P.fedkt_l2_epsilon([u.vote_gaps for u in updates],
                                      cfg.gamma, cfg.num_classes)
        return None
