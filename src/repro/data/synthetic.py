"""Synthetic dataset generators standing in for the paper's datasets.

The original Adult / cod-rna / MNIST / SVHN are not available offline
(DESIGN.md §2); these generators produce statistically analogous tasks so
the paper's *protocol* (splits, Dirichlet partition, s/t structure) and
*qualitative claims* can be reproduced exactly:

  tabular_binary : Gaussian-mixture tabular binary task ("adult"/"cod-rna")
  digits         : 10-class procedural image task ("mnist"/"svhn")
  tokens         : LM token streams with an ngram-ish latent process
                   (for the large-model distillation path)

All return dicts with train/public/test splits following the paper
(75/12.5/12.5 for tabular; public = half of test for images).
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def tabular_binary(n=20_000, num_features=14, seed=0,
                   class_sep=1.2) -> Dict[str, np.ndarray]:
    """Binary tabular task: mixture of 4 Gaussian clusters per class with
    a nonlinear (xor-ish) decision component — linearly inseparable, like
    Adult."""
    rng = np.random.default_rng(seed)
    n_clusters = 4
    means = rng.normal(0, 2.0, (2, n_clusters, num_features))
    X = np.empty((n, num_features), np.float32)
    y = rng.integers(0, 2, n)
    cl = rng.integers(0, n_clusters, n)
    X = means[y, cl] * class_sep + rng.normal(0, 1.0, (n, num_features))
    # nonlinear flip region to keep trees/NNs honest
    flip = (np.sin(X[:, 0]) * X[:, 1] > 1.5)
    y = np.where(flip, 1 - y, y).astype(np.int32)
    X = X.astype(np.float32)
    return _split_751212(X, y, rng)


def digits(n=12_000, image_size=16, num_classes=10, seed=0,
           noise=0.35) -> Dict[str, np.ndarray]:
    """Procedural 10-class image task: each class is a fixed stroke
    template; samples are jittered, scaled, noised copies (MNIST-like
    difficulty at 16x16)."""
    rng = np.random.default_rng(seed)
    # class templates: random smooth masks
    t = rng.normal(0, 1, (num_classes, image_size, image_size))
    for _ in range(3):  # smooth
        t = (t + np.roll(t, 1, 1) + np.roll(t, -1, 1)
             + np.roll(t, 1, 2) + np.roll(t, -1, 2)) / 5.0
    t = (t > 0.1).astype(np.float32)
    y = rng.integers(0, num_classes, n).astype(np.int32)
    shifts = rng.integers(-2, 3, (n, 2))
    X = np.empty((n, image_size, image_size, 1), np.float32)
    for i in range(n):
        img = np.roll(np.roll(t[y[i]], shifts[i, 0], 0), shifts[i, 1], 1)
        X[i, :, :, 0] = img * rng.uniform(0.7, 1.3) \
            + rng.normal(0, noise, (image_size, image_size))
    # images: public = half of "test pool", like the paper's MNIST split
    n_tr = int(n * 0.75)
    n_half = (n - n_tr) // 2
    return {"X_train": X[:n_tr], "y_train": y[:n_tr],
            "X_public": X[n_tr:n_tr + n_half],
            "y_public": y[n_tr:n_tr + n_half],
            "X_test": X[n_tr + n_half:], "y_test": y[n_tr + n_half:]}


def tokens(n_seqs=512, seq_len=128, vocab=512, seed=0,
           order=2) -> Dict[str, np.ndarray]:
    """Token streams from a random sparse bigram process (a learnable
    non-trivial LM task for the distillation path)."""
    rng = np.random.default_rng(seed)
    # sparse transition table: each context -> 8 likely next tokens
    nexts = rng.integers(0, vocab, (vocab, 8))
    seqs = np.empty((n_seqs, seq_len), np.int32)
    state = rng.integers(0, vocab, n_seqs)
    for tpos in range(seq_len):
        choose = rng.integers(0, 8, n_seqs)
        rand = rng.integers(0, vocab, n_seqs)
        use_rand = rng.random(n_seqs) < 0.1
        state = np.where(use_rand, rand, nexts[state, choose])
        seqs[:, tpos] = state
    n_tr = int(n_seqs * 0.75)
    n_half = (n_seqs - n_tr) // 2
    return {"train": seqs[:n_tr], "public": seqs[n_tr:n_tr + n_half],
            "test": seqs[n_tr + n_half:], "vocab": vocab}


def _split_751212(X, y, rng):
    n = len(X)
    idx = rng.permutation(n)
    X, y = X[idx], y[idx]
    n_tr = int(n * 0.75)
    n_pub = int(n * 0.125)
    return {"X_train": X[:n_tr], "y_train": y[:n_tr],
            "X_public": X[n_tr:n_tr + n_pub],
            "y_public": y[n_tr:n_tr + n_pub],
            "X_test": X[n_tr + n_pub:], "y_test": y[n_tr + n_pub:]}
