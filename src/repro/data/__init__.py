from repro.data.pipeline import TokenDataset, party_token_datasets  # noqa: F401
from repro.data import synthetic  # noqa: F401
