from repro.data.pipeline import (TokenDataset,  # noqa: F401
                                 lm_session_data, party_token_datasets,
                                 sequence_proxy_labels)
from repro.data import synthetic  # noqa: F401
