"""Data pipeline: batching, shuffling, party splits for the LM path.

Host-side (numpy) pipeline feeding device batches — deterministic,
seeded, with Dirichlet party partitioning reused from core/partition.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core.partition import dirichlet_partition


class TokenDataset:
    """(N, S+1) token matrix -> batches of {tokens, labels} (next-token)."""

    def __init__(self, seqs: np.ndarray, seed: int = 0):
        assert seqs.ndim == 2
        self.seqs = seqs.astype(np.int32)
        self.rng = np.random.default_rng(seed)

    def __len__(self):
        return len(self.seqs)

    def batches(self, batch_size: int, steps: Optional[int] = None,
                labels: Optional[np.ndarray] = None
                ) -> Iterator[Dict[str, np.ndarray]]:
        """Infinite (or ``steps``-bounded) shuffled batch stream.  If
        ``labels`` is given (distillation), they replace the shifted
        next-token labels."""
        n, produced = len(self.seqs), 0
        while steps is None or produced < steps:
            order = self.rng.permutation(n)
            for i in range(0, n - batch_size + 1, batch_size):
                idx = order[i:i + batch_size]
                seq = self.seqs[idx]
                if labels is not None:
                    yield {"tokens": seq[:, :-1], "labels": labels[idx]}
                else:
                    yield {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
                produced += 1
                if steps is not None and produced >= steps:
                    return


def party_token_datasets(seqs: np.ndarray, num_parties: int, beta: float,
                         seed: int = 0) -> List[TokenDataset]:
    """Dirichlet-heterogeneous split of sequences by their dominant token
    class (a proxy label so 'label skew' is meaningful for LM data)."""
    parts = dirichlet_partition(sequence_proxy_labels(seqs), num_parties,
                                beta, seed)
    return [TokenDataset(seqs[ix], seed + i) for i, ix in enumerate(parts)]


def sequence_proxy_labels(seqs: np.ndarray) -> np.ndarray:
    """Per-sequence proxy class (first token mod 10) so the Dirichlet
    'label skew' partition is meaningful for LM data."""
    return (seqs[:, 0] % 10).astype(np.int32)


def lm_session_data(train: np.ndarray, public: np.ndarray,
                    test: np.ndarray) -> Dict[str, np.ndarray]:
    """Token splits in the FedKTSession data schema.

    X_* are (N, S+1) int32 sequence matrices (an "example" is a
    sequence); ``y_train`` carries the proxy classes the partitioner
    skews over — the SAME proxy ``party_token_datasets`` uses, so the
    session reproduces the legacy LM loop's party split seed-for-seed.
    ``y_test`` is the flat next-token target stream matching
    ``LMLearner.predict``'s (N*S,) layout, making the session's
    ``accuracy`` metric next-token accuracy.
    """
    train = np.asarray(train, np.int32)
    test = np.asarray(test, np.int32)
    return {"X_train": train,
            "y_train": sequence_proxy_labels(train),
            "X_public": np.asarray(public, np.int32),
            "X_test": test,
            "y_test": test[:, 1:].reshape(-1)}
