from repro.optim.optimizers import (  # noqa: F401
    Optimizer, OptState, adamw, clip_by_global_norm, get, prox_grads, sgd,
)
from repro.optim.schedules import constant, warmup_cosine  # noqa: F401
