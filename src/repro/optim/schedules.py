"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr):
    return lambda step: jnp.float32(lr)


def warmup_cosine(lr, warmup_steps, total_steps, min_frac=0.1):
    def f(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.float32(step)
        warm = s / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * jnp.where(s < warmup_steps, warm, cos)
    return f
