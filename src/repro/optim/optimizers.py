"""Optimizers as pure (init, update) pairs over parameter pytrees.

No optax dependency — AdamW and SGD(+momentum) are implemented directly,
plus the FedProx proximal wrapper (adds mu*(w - w_global) to gradients)
used by the paper's baselines and by FedKT-Prox.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment / momentum
    nu: Any          # second moment (adam) or None-like zeros


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    # (grads, state, params, lr) -> (params, state)
    update: Callable[..., tuple]


def adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.int32(0), z,
                        jax.tree.map(jnp.zeros_like, z))

    def update(grads, state, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            mhat = m / (1 - b1 ** t)
            vhat = v / (1 - b2 ** t)
            d = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
                p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step, new_m, new_v)

    return Optimizer(init, update)


def sgd(momentum=0.0) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.int32(0), z, jnp.int32(0))

    def update(grads, state, params, lr):
        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        out = jax.tree.map(upd, grads, state.mu, params)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(state.step + 1, new_m, state.nu)

    return Optimizer(init, update)


def get(name: str, weight_decay=0.0) -> Optimizer:
    if name == "adamw":
        return adamw(weight_decay=weight_decay)
    if name == "sgd":
        return sgd()
    if name == "sgdm":
        return sgd(momentum=0.9)
    raise ValueError(name)


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def prox_grads(grads, params, global_params, mu: float):
    """FedProx: g <- g + mu * (w - w_global)."""
    return jax.tree.map(
        lambda g, p, gp: g + mu * (p.astype(jnp.float32)
                                   - gp.astype(jnp.float32)).astype(g.dtype),
        grads, params, global_params)
