"""Checkpointing: pytree <-> .npz with path-keyed arrays + JSON manifest.

Works for params, optimizer state, and FedKT student-model collections.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def flatten_tree(tree) -> Dict[str, Any]:
    """Path-keyed leaves: each leaf under its '/'-joined key path
    (dict keys and sequence indices).  Leaves are returned as-is, so
    this works on concrete arrays AND on ShapeDtypeStructs (abstract
    lowering).  Shared by checkpoint save/restore and the federation
    wire codec (federation/codec.py)."""
    flat = {}

    def f(kp, leaf):
        keys = []
        for k in kp:
            keys.append(str(getattr(k, "key", getattr(k, "idx", k))))
        flat[_SEP.join(keys)] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(f, tree)
    return flat


def save(path: str, tree, step: Optional[int] = None,
         metrics: Optional[Dict[str, Any]] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {p: np.asarray(l) for p, l in flatten_tree(tree).items()}
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    manifest = {"step": step, "metrics": metrics or {},
                "leaves": sorted(flat)}
    with open(path.removesuffix(".npz") + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like):
    """Restores into the structure of ``like`` (a pytree template)."""
    z = np.load(path if path.endswith(".npz") else path + ".npz")
    flat = dict(z)

    paths = []

    def collect(kp, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in kp]
        paths.append(_SEP.join(keys))
        return leaf

    jax.tree_util.tree_map_with_path(collect, like)
    leaves = [jnp.asarray(flat[p]) for p in paths]
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, leaves)


def manifest(path: str) -> Dict[str, Any]:
    with open(path.removesuffix(".npz") + ".json") as f:
        return json.load(f)
