from repro.checkpoint.checkpoint import manifest, restore, save  # noqa: F401
