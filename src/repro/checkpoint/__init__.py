from repro.checkpoint.checkpoint import (flatten_tree,  # noqa: F401
                                         manifest, restore, save)
