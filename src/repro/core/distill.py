"""LM-scale FedKT: the sharded distillation steps (pjit-able pure fns).

Three step kinds, mirroring Algorithm 1 at datacenter scale:

  label_step   — the teacher/student ensemble (params stacked on a
                 leading "member" axis, sharded across the party mesh
                 axis) greedily predicts the public batch; the blocked
                 vote op reduces one-hot votes across members.  Under
                 pjit the cross-member reduction lowers to ONE
                 all-reduce: the paper's single communication round.
  train_step   — student / final model update on voted labels (standard
                 CE + MoE aux), AdamW, global-norm clip.
  serve steps  — prefill / decode for the trained final model
                 (launch/serve.py wires shapes; defined here for reuse).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core.voting import token_teacher_vote
from repro.models import Model
from repro.optim import clip_by_global_norm, get as get_opt, warmup_cosine


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    opt = get_opt(tcfg.optimizer, weight_decay=tcfg.weight_decay)
    sched = warmup_cosine(tcfg.learning_rate, tcfg.warmup_steps,
                          max(tcfg.steps, 1))

    def train_step(params, opt_state, batch):
        # ZeRO-3 pre-gather: ONE bf16 all-gather per weight per step
        # (EXPERIMENTS.md §Perf iters 1 & 7).  The gather is hoisted
        # OUTSIDE the microbatch loop: we differentiate w.r.t. the
        # gathered bf16 copy and reshard the accumulated gradient back to
        # the (FSDP) param layout once — the bf16 reduce-scatter ZeRO
        # prescribes, at 1/m the naive wire cost.
        from repro.sharding import pregather_params as _pregather
        from repro.sharding.specs import (_ACT_MESH, _path_names,
                                          spec_for_param)
        from jax.sharding import NamedSharding

        def loss_fn(pcx, mb):
            return model.loss(pcx, mb, remat=tcfg.remat)

        pregather_params = (_pregather if tcfg.pregather
                            else lambda p, dtype: p)

        m = tcfg.microbatches
        if m <= 1:
            # single microbatch: pre-gather INSIDE the grad so expert/
            # weight gradients reduce-scatter in the FSDP layout directly
            # (hoisting here forces full-size gathered-layout grad
            # all-reduces — measured 3x wire regression on MoE, §Perf
            # iter 7b)
            def loss_inner(p, mb):
                return loss_fn(
                    pregather_params(p, jnp.dtype(model.cfg.dtype)), mb)

            loss, grads = jax.value_and_grad(loss_inner)(params, batch)
            grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
            lr = sched(opt_state.step + 1)
            params, opt_state = opt.update(grads, opt_state, params, lr)
            return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                       "lr": lr}
        pc = pregather_params(params, jnp.dtype(model.cfg.dtype))

        # gradient accumulation: activations scale 1/m (§Perf iter 5)
        def split(x):
            return x.reshape((m, x.shape[0] // m) + x.shape[1:])

        mbs = jax.tree.map(split, batch)

        def acc(carry, mb):
            l, g = jax.value_and_grad(loss_fn)(pc, mb)
            return (carry[0] + l / m,
                    jax.tree.map(lambda a, b: a + b / m, carry[1], g)), None

        from repro.kernels import ops as _ops
        zero = (jnp.float32(0.0),
                jax.tree.map(lambda p: jnp.zeros_like(p), pc))
        (loss, gpc), _ = jax.lax.scan(acc, zero, mbs,
                                      unroll=_ops.CONFIG["unroll"])

        # reshard grads back to the param (FSDP) layout, then promote f32
        mesh = _ACT_MESH[0]

        def reshard(kp, g, p):
            if mesh is not None and jnp.issubdtype(p.dtype, jnp.floating):
                spec = spec_for_param(_path_names(kp), p.shape, mesh)
                g = jax.lax.with_sharding_constraint(
                    g, NamedSharding(mesh, spec))
            return g.astype(jnp.float32)

        grads = jax.tree_util.tree_map_with_path(reshard, gpc, params)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = sched(opt_state.step + 1)   # step counts from 0: avoid lr=0
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                   "lr": lr}

    return train_step, opt


def make_label_step(model: Model, num_members: int,
                    gamma: float = 0.0) -> Callable:
    """FedKT vote step over ``num_members`` stacked parameter sets."""
    from repro.federation.domain import token_domain

    def label_step(member_params, batch, key=None):
        preds = jax.vmap(
            lambda p: model.predict(p, batch))(member_params)  # (M,B,S)
        # shapes are static at trace time, so the token domain (T = B*S
        # vote rows over the vocab) is a trace-time constant; it stays
        # anonymous here — only the callers hold the concrete queries
        dom = token_domain(preds.shape[1] * preds.shape[2],
                           model.cfg.vocab_size)
        labels, gap = token_teacher_vote(preds, dom, gamma=gamma, key=key)
        return labels, gap

    return label_step


def make_prefill_step(model: Model) -> Callable:
    def prefill(params, batch):
        logits, cache = model.logits(params, batch, mode="prefill")
        return logits[:, -1:], cache

    return prefill


def make_decode_step(model: Model) -> Callable:
    """Greedy decode step.  ``pos`` may be a scalar (every row at the
    same position — the fixed-batch ``serve_batch`` path) or a (B,)
    vector of per-row positions (the continuous-batching engine, where
    each cache slot is an independent stream)."""
    def decode(params, token, cache, pos):
        logits, cache = model.logits(params, {"tokens": token},
                                     mode="decode", cache=cache, pos=pos)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache

    return decode


def make_bucket_prefill_step(model: Model) -> Callable:
    """Prefill over a right-padded (b, Pb) prompt bucket.

    Each row's true prompt length ``plens[i] <= Pb`` picks the hidden
    state the first generated token is read from: with causal
    attention, position plens[i]-1 never attends a pad, so the token is
    bit-identical to an exact-length prefill of the same prompt.
    Returns (first_token (b,) int32, linear prefill cache) — the cache
    still holds all Pb (pad-polluted past plen) entries; the engine's
    ``Model.insert_cache`` handles placement and ring conversion."""
    from repro.models import transformer

    def prefill(params, tokens, plens):
        h, cache, _ = model.hidden(params, {"tokens": tokens},
                                   mode="prefill", remat=False)
        last = h[jnp.arange(h.shape[0]), plens - 1]          # (b, D)
        logits = transformer.logits_fn(model.cfg, params, last[:, None])
        tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return tok, cache

    return prefill
