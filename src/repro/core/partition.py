"""Data partitioning: the paper's federation structure.

Two levels:
  1. Dirichlet(beta) heterogeneous split of the global training set into
     n parties (the paper's protocol, following Yurochkin et al.):
     for each class k, sample p_k ~ Dir_n(beta) and give party j a
     p_{k,j} fraction of class-k examples.
  2. Within a party: s partitions, each covering the whole local dataset,
     each split into t disjoint equal subsets (Algorithm 1 line 2).
"""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(y: np.ndarray, num_parties: int, beta: float,
                        seed: int = 0, min_size: int = 2) -> List[np.ndarray]:
    """Returns per-party index arrays.  Retries until every party has at
    least ``min_size`` examples (paper's experimental practice)."""
    rng = np.random.default_rng(seed)
    n_classes = int(y.max()) + 1
    for _ in range(100):
        party_idx = [[] for _ in range(num_parties)]
        for k in range(n_classes):
            idx_k = np.where(y == k)[0]
            rng.shuffle(idx_k)
            p = rng.dirichlet([beta] * num_parties)
            cuts = (np.cumsum(p) * len(idx_k)).astype(int)[:-1]
            for j, part in enumerate(np.split(idx_k, cuts)):
                party_idx[j].extend(part.tolist())
        sizes = [len(ix) for ix in party_idx]
        if min(sizes) >= min_size:
            return [np.array(sorted(ix)) for ix in party_idx]
    raise RuntimeError("could not satisfy min_size partition")


def homogeneous_partition(n: int, num_parties: int,
                          seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.sort(a) for a in np.array_split(idx, num_parties)]


def subsets_of_partition(local_idx: np.ndarray, num_partitions: int,
                         num_subsets: int, seed: int = 0
                         ) -> List[List[np.ndarray]]:
    """Algorithm 1 line 2: s independent shuffles of the local data, each
    cut into t disjoint subsets.  Returns [partition][subset] -> indices."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(num_partitions):
        perm = rng.permutation(local_idx)
        out.append([np.sort(a) for a in np.array_split(perm, num_subsets)])
    return out
