"""Data partitioning: the paper's federation structure.

Two levels:
  1. Dirichlet(beta) heterogeneous split of the global training set into
     n parties (the paper's protocol, following Yurochkin et al.):
     for each class k, sample p_k ~ Dir_n(beta) and give party j a
     p_{k,j} fraction of class-k examples.
  2. Within a party: s partitions, each covering the whole local dataset,
     each split into t disjoint equal subsets (Algorithm 1 line 2).

Plus the VERTICAL scenario (``vertical_split``): every silo holds the
SAME samples but a disjoint slice of the feature columns (a hospital
holds labs, a bank holds transactions, keyed by the same patients).
Parties align rows by a shared sample-id vector and train
feature-masked learners (core.learners ``feature_mask=``); the vote
layout is unchanged — each party's students still emit one vote per
query example — so vertical silos ride the same (T, U) example domain
and the same one-shot protocol as horizontal ones.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def dirichlet_partition(y: np.ndarray, num_parties: int, beta: float,
                        seed: int = 0, min_size: int = 2) -> List[np.ndarray]:
    """Returns per-party index arrays.  Retries until every party has at
    least ``min_size`` examples (paper's experimental practice)."""
    rng = np.random.default_rng(seed)
    n_classes = int(y.max()) + 1
    for _ in range(100):
        party_idx = [[] for _ in range(num_parties)]
        for k in range(n_classes):
            idx_k = np.where(y == k)[0]
            rng.shuffle(idx_k)
            p = rng.dirichlet([beta] * num_parties)
            cuts = (np.cumsum(p) * len(idx_k)).astype(int)[:-1]
            for j, part in enumerate(np.split(idx_k, cuts)):
                party_idx[j].extend(part.tolist())
        sizes = [len(ix) for ix in party_idx]
        if min(sizes) >= min_size:
            return [np.array(sorted(ix)) for ix in party_idx]
    raise RuntimeError("could not satisfy min_size partition")


def homogeneous_partition(n: int, num_parties: int,
                          seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.sort(a) for a in np.array_split(idx, num_parties)]


def vertical_split(sample_ids: np.ndarray, num_features: int,
                   num_parties: int, seed: int = 0
                   ) -> Tuple[np.ndarray, List[Tuple[int, ...]]]:
    """Feature-sliced federation: n parties hold the SAME samples and
    disjoint column slices.

    ``sample_ids`` is the shared join key — each silo stores its slice
    keyed by these ids, in whatever order its own storage uses.
    Returns:

      row_order     : indices that put the samples in canonical
                      ascending-id order.  EVERY party applies this
                      order to its local rows, so row i means the same
                      sample at every silo — the alignment the vote
                      depends on (votes are summed per query row).
      feature_masks : one sorted tuple of column indices per party, a
                      seeded disjoint cover of range(num_features).
                      Tuples (not arrays) because learners carry the
                      mask as a hashable jit-static field
                      (core.learners ``feature_mask=``).

    Raises on duplicate sample ids (an ambiguous join) and on more
    parties than feature columns.
    """
    ids = np.asarray(sample_ids)
    if len(np.unique(ids)) != len(ids):
        raise ValueError("vertical_split needs unique sample ids: the "
                         "id vector is the cross-silo row join key")
    if num_parties > num_features:
        raise ValueError(f"cannot slice {num_features} feature columns "
                         f"across {num_parties} parties")
    row_order = np.argsort(ids, kind="stable")
    rng = np.random.default_rng(seed)
    cols = rng.permutation(num_features)
    feature_masks = [tuple(int(c) for c in sorted(part))
                     for part in np.array_split(cols, num_parties)]
    return row_order, feature_masks


def subsets_of_partition(local_idx: np.ndarray, num_partitions: int,
                         num_subsets: int, seed: int = 0
                         ) -> List[List[np.ndarray]]:
    """Algorithm 1 line 2: s independent shuffles of the local data, each
    cut into t disjoint subsets.  Returns [partition][subset] -> indices."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(num_partitions):
        perm = rng.permutation(local_idx)
        out.append([np.sort(a) for a in np.array_split(perm, num_subsets)])
    return out
