"""Non-differentiable learners in pure JAX: random forest and GBDT.

FedKT's headline claim is model-agnosticism — it federates models that
FedAvg cannot (paper Table 1 trains a random forest on Adult and a GBDT
on cod-rna).  These are histogram-based, fixed-depth, fully-vectorized
tree learners: every depth level builds (node, feature, bin) histograms
with one scatter-add over the whole dataset, so tree fitting is a single
jit-compiled program and forests fit under vmap.

Trees are complete binary trees in heap layout:
  split_feat/split_bin : (2^depth - 1,)  internal nodes
  leaf                 : (2^depth, C)    class scores / regression values
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

NUM_BINS = 32


# ---------------------------------------------------------------------------
# Binning
# ---------------------------------------------------------------------------
def make_bins(X: np.ndarray, num_bins: int = NUM_BINS) -> np.ndarray:
    """Per-feature quantile bin edges: (F, num_bins - 1)."""
    qs = np.linspace(0, 100, num_bins + 1)[1:-1]
    return np.percentile(X, qs, axis=0).T.astype(np.float32)


def binize(X, edges) -> jnp.ndarray:
    """X: (N, F) -> int32 bins (N, F) in [0, num_bins)."""
    return jnp.sum(X[:, :, None] >= edges[None], axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Classification tree (gini)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("depth", "num_classes",
                                             "num_bins"))
def fit_tree_gini(xb, y, w, feat_mask, *, depth, num_classes,
                  num_bins=NUM_BINS):
    """xb: (N, F) int32 bins; y: (N,) int32; w: (N,) f32 sample weights
    (bootstrap); feat_mask: (F,) f32 in {0,1}.  Returns tree arrays."""
    N, F = xb.shape
    C = num_classes
    n_internal = 2 ** depth - 1
    split_feat = jnp.zeros((n_internal,), jnp.int32)
    split_bin = jnp.zeros((n_internal,), jnp.int32)
    node = jnp.zeros((N,), jnp.int32)

    for level in range(depth):
        n_nodes = 2 ** level
        base = n_nodes - 1
        # hist: (node, feature, bin, class) weighted counts
        flat = ((node[:, None] * F + jnp.arange(F)[None]) * num_bins
                + xb) * C + y[:, None]
        hist = jnp.zeros((n_nodes * F * num_bins * C,), jnp.float32)
        hist = hist.at[flat.reshape(-1)].add(
            jnp.broadcast_to(w[:, None], (N, F)).reshape(-1))
        hist = hist.reshape(n_nodes, F, num_bins, C)

        left = jnp.cumsum(hist, axis=2)                   # split at bin<=b
        total = left[:, :, -1:, :]
        right = total - left
        ln = left.sum(-1)                                  # (n,F,B)
        rn = right.sum(-1)
        gini_l = ln - (left ** 2).sum(-1) / jnp.maximum(ln, 1e-9)
        gini_r = rn - (right ** 2).sum(-1) / jnp.maximum(rn, 1e-9)
        score = -(gini_l + gini_r)                         # maximize
        # last bin => empty right split; mask it and masked features
        score = score.at[:, :, -1].set(-jnp.inf)
        score = jnp.where(feat_mask[None, :, None] > 0, score, -jnp.inf)

        flat_best = jnp.argmax(score.reshape(n_nodes, -1), axis=1)
        bf = (flat_best // num_bins).astype(jnp.int32)     # (n_nodes,)
        bb = (flat_best % num_bins).astype(jnp.int32)
        split_feat = jax.lax.dynamic_update_slice(split_feat, bf, (base,))
        split_bin = jax.lax.dynamic_update_slice(split_bin, bb, (base,))

        f_n = bf[node]                                     # (N,)
        b_n = bb[node]
        go_right = xb[jnp.arange(N), f_n] > b_n
        node = 2 * node + go_right.astype(jnp.int32)

    # leaves: class histograms
    flat = node * C + y
    leaf = jnp.zeros((2 ** depth * C,), jnp.float32).at[flat].add(w)
    leaf = leaf.reshape(2 ** depth, C)
    leaf = leaf / jnp.maximum(leaf.sum(-1, keepdims=True), 1e-9)
    return split_feat, split_bin, leaf


def tree_apply(tree, xb):
    """Returns per-sample leaf rows (N, C)."""
    split_feat, split_bin, leaf = tree
    N = xb.shape[0]
    depth = int(np.log2(leaf.shape[0]))
    node = jnp.zeros((N,), jnp.int32)
    for level in range(depth):
        base = 2 ** level - 1
        f = split_feat[base + node]
        b = split_bin[base + node]
        node = 2 * node + (xb[jnp.arange(N), f] > b).astype(jnp.int32)
    return leaf[node]


# ---------------------------------------------------------------------------
# Random forest
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RandomForest:
    num_trees: int = 20
    depth: int = 6
    num_classes: int = 2
    feature_frac: float = 0.7

    def fit(self, key, X, y, edges):
        xb = binize(X, edges)
        N, F = xb.shape
        kb, kf = jax.random.split(key)
        # bootstrap via draw-with-replacement counts as sample weights
        # (multinomial(N, uniform) == histogram of N uniform draws)
        idx = jax.random.randint(kb, (self.num_trees, N), 0, N)
        w = jax.vmap(lambda r: jnp.bincount(r, length=N))(idx).astype(
            jnp.float32)
        fm = (jax.random.uniform(kf, (self.num_trees, F))
              < self.feature_frac).astype(jnp.float32)
        fm = jnp.maximum(fm, jnp.zeros_like(fm).at[:, 0].set(1.0))

        fit_one = functools.partial(fit_tree_gini, depth=self.depth,
                                    num_classes=self.num_classes)
        return jax.vmap(lambda wi, fi: fit_one(xb, y, wi, fi))(w, fm)

    def predict(self, forest, X, edges):
        xb = binize(X, edges)
        probs = jax.vmap(lambda t: tree_apply(t, xb))(forest)  # (T,N,C)
        return jnp.argmax(probs.mean(0), axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# GBDT (binary, logistic loss, XGBoost-style gains)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("depth", "num_bins"))
def fit_tree_gh(xb, g, h, *, depth, num_bins=NUM_BINS, lam=1.0):
    """Regression tree on gradients/hessians.  Returns tree arrays with
    scalar leaves (2^depth, 1)."""
    N, F = xb.shape
    n_internal = 2 ** depth - 1
    split_feat = jnp.zeros((n_internal,), jnp.int32)
    split_bin = jnp.zeros((n_internal,), jnp.int32)
    node = jnp.zeros((N,), jnp.int32)

    for level in range(depth):
        n_nodes = 2 ** level
        base = n_nodes - 1
        flat = (node[:, None] * F + jnp.arange(F)[None]) * num_bins + xb
        gh = jnp.zeros((2, n_nodes * F * num_bins), jnp.float32)
        gh = gh.at[0, flat.reshape(-1)].add(
            jnp.broadcast_to(g[:, None], (N, F)).reshape(-1))
        gh = gh.at[1, flat.reshape(-1)].add(
            jnp.broadcast_to(h[:, None], (N, F)).reshape(-1))
        G = gh[0].reshape(n_nodes, F, num_bins)
        H = gh[1].reshape(n_nodes, F, num_bins)
        GL, HL = jnp.cumsum(G, 2), jnp.cumsum(H, 2)
        GT, HT = GL[:, :, -1:], HL[:, :, -1:]
        GR, HR = GT - GL, HT - HL
        gain = GL ** 2 / (HL + lam) + GR ** 2 / (HR + lam) \
            - GT ** 2 / (HT + lam)
        gain = gain.at[:, :, -1].set(-jnp.inf)

        flat_best = jnp.argmax(gain.reshape(n_nodes, -1), axis=1)
        bf = (flat_best // num_bins).astype(jnp.int32)
        bb = (flat_best % num_bins).astype(jnp.int32)
        split_feat = jax.lax.dynamic_update_slice(split_feat, bf, (base,))
        split_bin = jax.lax.dynamic_update_slice(split_bin, bb, (base,))
        f_n, b_n = bf[node], bb[node]
        node = 2 * node + (xb[jnp.arange(N), f_n] > b_n).astype(jnp.int32)

    n_leaves = 2 ** depth
    Gs = jnp.zeros((n_leaves,), jnp.float32).at[node].add(g)
    Hs = jnp.zeros((n_leaves,), jnp.float32).at[node].add(h)
    leaf = (-Gs / (Hs + lam))[:, None]
    return split_feat, split_bin, leaf


@dataclass(frozen=True)
class GBDT:
    num_rounds: int = 30
    depth: int = 6
    learning_rate: float = 0.3
    num_classes: int = 2  # binary only

    def fit(self, key, X, y, edges):
        xb = binize(X, edges)
        yf = y.astype(jnp.float32)
        logits = jnp.zeros((X.shape[0],), jnp.float32)
        trees = []
        for _ in range(self.num_rounds):
            p = jax.nn.sigmoid(logits)
            tree = fit_tree_gh(xb, p - yf, p * (1 - p), depth=self.depth)
            logits = logits + self.learning_rate * tree_apply(tree, xb)[:, 0]
            trees.append(tree)
        return jax.tree.map(lambda *t: jnp.stack(t), *trees)

    def predict(self, trees, X, edges):
        xb = binize(X, edges)
        vals = jax.vmap(lambda t: tree_apply(t, xb)[:, 0])(trees)
        logits = self.learning_rate * vals.sum(0)
        return (logits > 0).astype(jnp.int32)
