"""Non-differentiable learners in pure JAX: random forest and GBDT.

FedKT's headline claim is model-agnosticism — it federates models that
FedAvg cannot (paper Table 1 trains a random forest on Adult and a GBDT
on cod-rna).  These are histogram-based, fixed-depth, fully-vectorized
tree learners: every depth level builds (node, feature, bin) histograms
over the whole dataset via ``ops.tree_hist`` — a blocked one-hot-matmul
formulation (Pallas kernel on TPU, restructured XLA matmul elsewhere)
that replaces the old giant scatter-add — so tree fitting is a single
jit-compiled program and forests fit under vmap.

Trees are complete binary trees in heap layout:
  split_feat/split_bin : (2^depth - 1,)  internal nodes
  leaf                 : (2^depth, C)    class scores / regression values

Every fit takes an ``impl`` knob ("auto" | "kernel" |
"kernel_interpret" | "xla") forwarded to ``ops.tree_hist`` — the same
dispatch convention as ``ops.votes``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

NUM_BINS = 32


# ---------------------------------------------------------------------------
# Binning
# ---------------------------------------------------------------------------
def make_bins(X: np.ndarray, num_bins: int = NUM_BINS) -> np.ndarray:
    """Per-feature quantile bin edges: (F, num_bins - 1)."""
    qs = np.linspace(0, 100, num_bins + 1)[1:-1]
    return np.percentile(X, qs, axis=0).T.astype(np.float32)


def binize(X, edges) -> jnp.ndarray:
    """X: (N, F) -> int32 bins (N, F) in [0, num_bins).

    bin = #{edges e : x >= e}, computed as a per-feature searchsorted
    (edges are sorted ascending) — O(N F log B) instead of the old
    O(N F B) broadcast-compare, and no (N, F, B) intermediate.
    """
    return jax.vmap(
        lambda col, e: jnp.searchsorted(e, col, side="right"),
        in_axes=(1, 0), out_axes=1)(X, edges).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Classification tree (gini)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("depth", "num_classes",
                                             "num_bins", "impl"))
def fit_tree_gini(xb, y, w, feat_mask, *, depth, num_classes,
                  num_bins=NUM_BINS, impl="auto"):
    """xb: (N, F) int32 bins; y: (N,) int32; w: (N,) f32 sample weights
    (bootstrap); feat_mask: (F,) f32 in {0,1}.  Returns tree arrays."""
    N, F = xb.shape
    C = num_classes
    n_internal = 2 ** depth - 1
    split_feat = jnp.zeros((n_internal,), jnp.int32)
    split_bin = jnp.zeros((n_internal,), jnp.int32)
    node = jnp.zeros((N,), jnp.int32)
    # class-masked sample weights: channel c holds w where y == c, so a
    # single tree_hist emits the (node, feature, bin, class) counts
    wc = jax.nn.one_hot(y, C, dtype=jnp.float32).T * w[None]       # (C, N)

    for level in range(depth):
        n_nodes = 2 ** level
        base = n_nodes - 1
        hist = ops.tree_hist(xb, node, wc, num_nodes=n_nodes,
                             num_bins=num_bins, impl=impl)
        hist = hist.transpose(1, 2, 3, 0)                 # (n, F, B, C)

        left = jnp.cumsum(hist, axis=2)                   # split at bin<=b
        total = left[:, :, -1:, :]
        right = total - left
        ln = left.sum(-1)                                  # (n,F,B)
        rn = right.sum(-1)
        gini_l = ln - (left ** 2).sum(-1) / jnp.maximum(ln, 1e-9)
        gini_r = rn - (right ** 2).sum(-1) / jnp.maximum(rn, 1e-9)
        score = -(gini_l + gini_r)                         # maximize
        # last bin => empty right split; mask it and masked features
        score = score.at[:, :, -1].set(-jnp.inf)
        score = jnp.where(feat_mask[None, :, None] > 0, score, -jnp.inf)

        flat_best = jnp.argmax(score.reshape(n_nodes, -1), axis=1)
        bf = (flat_best // num_bins).astype(jnp.int32)     # (n_nodes,)
        bb = (flat_best % num_bins).astype(jnp.int32)
        split_feat = jax.lax.dynamic_update_slice(split_feat, bf, (base,))
        split_bin = jax.lax.dynamic_update_slice(split_bin, bb, (base,))

        f_n = bf[node]                                     # (N,)
        b_n = bb[node]
        go_right = xb[jnp.arange(N), f_n] > b_n
        node = 2 * node + go_right.astype(jnp.int32)

    # leaves: class histograms
    leaf = ops.node_hist(node, wc, num_nodes=2 ** depth, impl=impl).T
    leaf = leaf / jnp.maximum(leaf.sum(-1, keepdims=True), 1e-9)
    return split_feat, split_bin, leaf


def tree_apply(tree, xb):
    """Returns per-sample leaf rows (N, C)."""
    split_feat, split_bin, leaf = tree
    N = xb.shape[0]
    depth = int(np.log2(leaf.shape[0]))
    node = jnp.zeros((N,), jnp.int32)
    for level in range(depth):
        base = 2 ** level - 1
        f = split_feat[base + node]
        b = split_bin[base + node]
        node = 2 * node + (xb[jnp.arange(N), f] > b).astype(jnp.int32)
    return leaf[node]


# ---------------------------------------------------------------------------
# Random forest
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("depth", "num_classes",
                                             "num_bins", "impl"))
def fit_forest(xb, y, w, fm, *, depth, num_classes, num_bins=NUM_BINS,
               impl="auto"):
    """One forest: vmap of fit_tree_gini over the tree axis.
    w: (T, N) per-tree sample weights; fm: (T, F) feature masks."""
    fit_one = functools.partial(fit_tree_gini, depth=depth,
                                num_classes=num_classes, num_bins=num_bins,
                                impl=impl)
    return jax.vmap(lambda wi, fi: fit_one(xb, y, wi, fi))(w, fm)


@functools.partial(jax.jit, static_argnames=("depth", "num_classes",
                                             "num_bins", "impl"))
def fit_forest_stacked(X, edges, y, w, fm, *, depth, num_classes,
                       num_bins=NUM_BINS, impl="auto"):
    """k forests as one batched fit.  X: (k, M, F) f32 rows padded to a
    shared bucket M; edges: (k, F, num_bins-1); y: (k, M); w: (k, T, M);
    fm: (k, T, F).  Padding rows ride at w == 0: every histogram and
    leaf build sees only exact zeros for them, so each stacked tree is
    bit-identical to its serial fit regardless of bucket size."""

    def fit_one_forest(Xi, ei, yi, wi, fi):
        return fit_forest(binize(Xi, ei), yi, wi, fi, depth=depth,
                          num_classes=num_classes, num_bins=num_bins,
                          impl=impl)

    return jax.vmap(fit_one_forest)(X, edges, y, w, fm)


def _forest_probs(forest, xb):
    probs = jax.vmap(lambda t: tree_apply(t, xb))(forest)      # (T,N,C)
    return probs.mean(0)


@jax.jit
def predict_forest_stacked(forests, X, edges):
    """(k,) stacked forests on one shared X -> (k, N) int32 labels."""

    def one(forest, e):
        return jnp.argmax(_forest_probs(forest, binize(X, e)),
                          axis=-1).astype(jnp.int32)

    return jax.vmap(one)(forests, edges)


@dataclass(frozen=True)
class RandomForest:
    num_trees: int = 20
    depth: int = 6
    num_classes: int = 2
    feature_frac: float = 0.7
    impl: str = "auto"            # histogram backend (ops.tree_hist)

    def bootstrap(self, key, N, F):
        """Per-tree bootstrap weights (T, N) and feature masks (T, F).
        Drawn at the TRUE dataset size N — the stacked fit calls this
        per dataset before padding, so a teacher's draw never depends on
        the shared bucket and key usage matches ``fit`` split-for-split."""
        kb, kf = jax.random.split(key)
        # bootstrap via draw-with-replacement counts as sample weights
        # (multinomial(N, uniform) == histogram of N uniform draws)
        idx = jax.random.randint(kb, (self.num_trees, N), 0, N)
        w = jax.vmap(lambda r: jnp.bincount(r, length=N))(idx).astype(
            jnp.float32)
        fm = (jax.random.uniform(kf, (self.num_trees, F))
              < self.feature_frac).astype(jnp.float32)
        fm = jnp.maximum(fm, jnp.zeros_like(fm).at[:, 0].set(1.0))
        return w, fm

    def fit(self, key, X, y, edges):
        xb = binize(X, edges)
        N, F = xb.shape
        w, fm = self.bootstrap(key, N, F)
        return fit_forest(xb, y, w, fm, depth=self.depth,
                          num_classes=self.num_classes, impl=self.impl)

    def predict(self, forest, X, edges):
        xb = binize(X, edges)
        return jnp.argmax(_forest_probs(forest, xb),
                          axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# GBDT (binary, logistic loss, XGBoost-style gains)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("depth", "num_bins", "impl"))
def fit_tree_gh(xb, g, h, *, depth, num_bins=NUM_BINS, lam=1.0,
                impl="auto"):
    """Regression tree on gradients/hessians.  Returns tree arrays with
    scalar leaves (2^depth, 1)."""
    N, F = xb.shape
    n_internal = 2 ** depth - 1
    split_feat = jnp.zeros((n_internal,), jnp.int32)
    split_bin = jnp.zeros((n_internal,), jnp.int32)
    node = jnp.zeros((N,), jnp.int32)
    gh_w = jnp.stack([g, h])                                   # (2, N)

    for level in range(depth):
        n_nodes = 2 ** level
        base = n_nodes - 1
        gh = ops.tree_hist(xb, node, gh_w, num_nodes=n_nodes,
                           num_bins=num_bins, impl=impl)   # (2, n, F, B)
        G, H = gh[0], gh[1]
        GL, HL = jnp.cumsum(G, 2), jnp.cumsum(H, 2)
        GT, HT = GL[:, :, -1:], HL[:, :, -1:]
        GR, HR = GT - GL, HT - HL
        gain = GL ** 2 / (HL + lam) + GR ** 2 / (HR + lam) \
            - GT ** 2 / (HT + lam)
        gain = gain.at[:, :, -1].set(-jnp.inf)

        flat_best = jnp.argmax(gain.reshape(n_nodes, -1), axis=1)
        bf = (flat_best // num_bins).astype(jnp.int32)
        bb = (flat_best % num_bins).astype(jnp.int32)
        split_feat = jax.lax.dynamic_update_slice(split_feat, bf, (base,))
        split_bin = jax.lax.dynamic_update_slice(split_bin, bb, (base,))
        f_n, b_n = bf[node], bb[node]
        node = 2 * node + (xb[jnp.arange(N), f_n] > b_n).astype(jnp.int32)

    GHs = ops.node_hist(node, gh_w, num_nodes=2 ** depth, impl=impl)
    leaf = (-GHs[0] / (GHs[1] + lam))[:, None]
    return split_feat, split_bin, leaf


@functools.partial(jax.jit, static_argnames=("num_rounds", "depth",
                                             "num_bins", "impl"))
def fit_gbdt(xb, y, w, lr, *, num_rounds, depth, num_bins=NUM_BINS,
             impl="auto"):
    """Full boosting loop as ONE jitted lax.scan over rounds (the former
    Python loop re-dispatched an un-jitted ``tree_apply`` every round).

    w: (N,) f32 masks the gradients/hessians — rows padded into a shared
    bucket ride at w == 0 and contribute exact zeros to every G/H
    histogram and leaf sum, so padding never changes a split or leaf."""
    yf = y.astype(jnp.float32)

    def boost_round(logits, _):
        p = jax.nn.sigmoid(logits)
        tree = fit_tree_gh(xb, (p - yf) * w, (p * (1.0 - p)) * w,
                           depth=depth, num_bins=num_bins, impl=impl)
        logits = logits + lr * tree_apply(tree, xb)[:, 0]
        return logits, tree

    _, trees = jax.lax.scan(boost_round,
                            jnp.zeros((xb.shape[0],), jnp.float32),
                            None, length=num_rounds)
    return trees                       # leaves stacked over rounds (R, ...)


@functools.partial(jax.jit, static_argnames=("num_rounds", "depth",
                                             "num_bins", "impl"))
def fit_gbdt_stacked(X, edges, y, w, lr, *, num_rounds, depth,
                     num_bins=NUM_BINS, impl="auto"):
    """k GBDTs as one batched fit.  X: (k, M, F) rows padded to a shared
    bucket; edges: (k, F, num_bins-1); y: (k, M); w: (k, M) zero on
    padding rows (see fit_gbdt)."""

    def one(Xi, ei, yi, wi):
        return fit_gbdt(binize(Xi, ei), yi, wi, lr, num_rounds=num_rounds,
                        depth=depth, num_bins=num_bins, impl=impl)

    return jax.vmap(one)(X, edges, y, w)


def _gbdt_logits(trees, xb, lr):
    vals = jax.vmap(lambda t: tree_apply(t, xb)[:, 0])(trees)
    return lr * vals.sum(0)


@jax.jit
def predict_gbdt_stacked(trees, X, edges, lr):
    """(k,) stacked GBDTs on one shared X -> (k, N) int32 labels."""

    def one(ti, ei):
        return (_gbdt_logits(ti, binize(X, ei), lr) > 0).astype(jnp.int32)

    return jax.vmap(one)(trees, edges)


@dataclass(frozen=True)
class GBDT:
    num_rounds: int = 30
    depth: int = 6
    learning_rate: float = 0.3
    num_classes: int = 2  # binary only
    impl: str = "auto"            # histogram backend (ops.tree_hist)

    def fit(self, key, X, y, edges, w=None):
        xb = binize(X, edges)
        if w is None:
            w = jnp.ones((xb.shape[0],), jnp.float32)
        return fit_gbdt(xb, y, w, self.learning_rate,
                        num_rounds=self.num_rounds, depth=self.depth,
                        impl=self.impl)

    def predict(self, trees, X, edges):
        xb = binize(X, edges)
        return (_gbdt_logits(trees, xb, self.learning_rate)
                > 0).astype(jnp.int32)
