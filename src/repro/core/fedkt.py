"""FedKT — Algorithm 1, legacy entry points.

.. deprecated::
    The single-round orchestration moved to :mod:`repro.federation`:
    ``FedKTSession`` drives the round (with pluggable "loop"/"vmap"
    engines), and SOLO / centralized-PATE are
    :mod:`repro.federation.strategies`.  The functions here are thin
    wrappers kept for source compatibility; they reproduce the original
    results seed-for-seed (test-enforced) and will be removed once all
    callers migrate.

This module remains the *small-model / generic-learner* path (tabular +
image tasks, any Learner including trees).  The LM-scale sharded path
lives in core/distill.py + launch/.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.configs.base import FedKTConfig

# NOTE: repro.federation is imported inside the wrappers — this module is
# re-exported from repro.core.__init__, and federation's submodules import
# their core dependencies through the same package init.


@dataclass
class FedKTResult:
    final_state: Any
    accuracy: float
    student_states: List[List[Any]]
    epsilon: Optional[float] = None
    solo_accuracy: Optional[float] = None
    meta: Dict[str, Any] = field(default_factory=dict)


def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"{old} is deprecated; use {new} instead",
                  DeprecationWarning, stacklevel=3)


def run_fedkt(learner, data: Dict[str, np.ndarray], cfg: FedKTConfig,
              *, student_learner=None, final_learner=None,
              party_indices=None, verbose=False) -> FedKTResult:
    """Deprecated wrapper over ``FedKTSession(engine="loop").run()``.

    data: X_train/y_train/X_public/(y_public)/X_test/y_test arrays.
    ``learner`` trains the teachers; students/final default to the same
    learner (the paper's setting).
    """
    from repro.federation.session import FedKTSession
    _deprecated("run_fedkt", "repro.federation.FedKTSession")
    session = FedKTSession(learner, data, cfg,
                           student_learner=student_learner,
                           final_learner=final_learner, engine="loop",
                           party_indices=party_indices)
    res = session.run(verbose=verbose)
    return FedKTResult(final_state=res.final_state, accuracy=res.accuracy,
                       student_states=res.student_states,
                       epsilon=res.epsilon, meta=res.meta)


def run_solo(learner, data, cfg: FedKTConfig,
             party_indices=None) -> float:
    """Deprecated wrapper over ``SoloStrategy`` (paper Table 1)."""
    from repro.federation.strategies import SoloStrategy
    _deprecated("run_solo", "repro.federation.SoloStrategy")
    return SoloStrategy(learner).run(data, cfg,
                                     party_indices=party_indices).accuracy


def run_pate_central(learner, data, cfg: FedKTConfig,
                     num_teachers=None) -> float:
    """Deprecated wrapper over ``CentralPATEStrategy``."""
    from repro.federation.strategies import CentralPATEStrategy
    _deprecated("run_pate_central", "repro.federation.CentralPATEStrategy")
    return CentralPATEStrategy(learner, num_teachers).run(data, cfg).accuracy
