"""FedKT — Algorithm 1, end to end.

Single communication round:
  party side : s partitions x t teachers -> vote on D_aux -> s students
  server side: n*s students -> consistent vote on D_aux -> final model
Privacy levels L0 / L1 (server Laplace) / L2 (party Laplace) with the
data-dependent moments accountant from privacy.py.

This module is the *small-model / generic-learner* orchestration used by
the paper's experiments (tabular + image tasks, any Learner including
trees).  The LM-scale sharded path lives in core/distill.py + launch/.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedKTConfig
from repro.core import privacy as P
from repro.core.learners import accuracy
from repro.core.partition import dirichlet_partition, subsets_of_partition
from repro.core.voting import consistent_vote, teacher_vote


@dataclass
class FedKTResult:
    final_state: Any
    accuracy: float
    student_states: List[List[Any]]
    epsilon: Optional[float] = None
    solo_accuracy: Optional[float] = None
    meta: Dict[str, Any] = field(default_factory=dict)


def run_fedkt(learner, data: Dict[str, np.ndarray], cfg: FedKTConfig,
              *, student_learner=None, final_learner=None,
              party_indices=None, verbose=False) -> FedKTResult:
    """data: X_train/y_train/X_public/(y_public)/X_test/y_test arrays.

    ``learner`` trains the teachers; students/final default to the same
    learner (the paper's setting).  Returns the trained final model plus
    test accuracy and (for L1/L2) the data-dependent epsilon.
    """
    student_learner = student_learner or learner
    final_learner = final_learner or learner
    key = jax.random.PRNGKey(cfg.seed)
    rng = np.random.default_rng(cfg.seed)

    Xtr, ytr = data["X_train"], data["y_train"]
    Xpub = data["X_public"]
    n, s, t, u = (cfg.num_parties, cfg.num_partitions, cfg.num_subsets,
                  cfg.num_classes)

    if party_indices is None:
        party_indices = dirichlet_partition(ytr, n, cfg.beta, cfg.seed)

    # number of public queries actually labelled (DP budget knob)
    Tq_party = len(Xpub) if cfg.privacy_level != "L2" else max(
        1, int(len(Xpub) * cfg.query_fraction))
    Tq_server = len(Xpub) if cfg.privacy_level != "L1" else max(
        1, int(len(Xpub) * cfg.query_fraction))

    student_states: List[List[Any]] = []
    party_gaps: List[np.ndarray] = []          # L2 accounting
    for i in range(n):
        plan = subsets_of_partition(party_indices[i], s, t,
                                    seed=cfg.seed + 17 * i)
        students_i = []
        gaps_i = []
        for j in range(s):
            teacher_states = []
            for k_sub, sub_idx in enumerate(plan[j]):
                key, kk = jax.random.split(key)
                teacher_states.append(
                    learner.fit(kk, Xtr[sub_idx], ytr[sub_idx]))
            preds = jnp.stack([
                learner.predict(st, Xpub[:Tq_party])
                for st in teacher_states])              # (t, Tq)
            key, kk = jax.random.split(key)
            gamma = cfg.gamma if cfg.privacy_level == "L2" else 0.0
            vote = teacher_vote(preds, u, gamma=gamma, key=kk)
            gaps_i.append(np.asarray(vote.top_gap))
            key, kk = jax.random.split(key)
            students_i.append(student_learner.fit(
                kk, Xpub[:Tq_party], np.asarray(vote.labels)))
        student_states.append(students_i)
        party_gaps.append(np.concatenate(gaps_i))
        if verbose:
            print(f"party {i}: {len(party_indices[i])} examples, "
                  f"{s}x{t} teachers trained")

    # ---- server side ----
    student_preds = jnp.stack([
        jnp.stack([student_learner.predict(st, Xpub[:Tq_server])
                   for st in students_i])
        for students_i in student_states])              # (n, s, Tq)
    key, kk = jax.random.split(key)
    gamma = cfg.gamma if cfg.privacy_level == "L1" else 0.0
    vote = consistent_vote(student_preds, u,
                           consistent=cfg.consistent_voting,
                           gamma=gamma, key=kk)
    key, kk = jax.random.split(key)
    final_state = final_learner.fit(kk, Xpub[:Tq_server],
                                    np.asarray(vote.labels))

    acc = accuracy(final_learner, final_state, data["X_test"],
                   data["y_test"])

    eps = None
    if cfg.privacy_level == "L1":
        # party-level: gap in party units is gap/s (consistent voting
        # moves counts in multiples of s)
        eps = P.fedkt_l1_epsilon(
            np.asarray(vote.counts), cfg.gamma, s, u, exact=True)
    elif cfg.privacy_level == "L2":
        eps = P.fedkt_l2_epsilon(party_gaps, cfg.gamma, u)

    return FedKTResult(final_state=final_state, accuracy=acc,
                       student_states=student_states, epsilon=eps,
                       meta={"party_sizes": [len(ix) for ix in
                                             party_indices]})


def run_solo(learner, data, cfg: FedKTConfig,
             party_indices=None) -> float:
    """SOLO baseline: mean per-party local accuracy (paper Table 1)."""
    key = jax.random.PRNGKey(cfg.seed + 1)
    Xtr, ytr = data["X_train"], data["y_train"]
    if party_indices is None:
        party_indices = dirichlet_partition(ytr, cfg.num_parties, cfg.beta,
                                            cfg.seed)
    accs = []
    for ix in party_indices:
        key, kk = jax.random.split(key)
        st = learner.fit(kk, Xtr[ix], ytr[ix])
        accs.append(accuracy(learner, st, data["X_test"], data["y_test"]))
    return float(np.mean(accs))


def run_pate_central(learner, data, cfg: FedKTConfig,
                     num_teachers=None) -> float:
    """Centralized PATE upper bound (paper baseline 2): split the WHOLE
    training set into teachers, vote on D_aux, train one student."""
    key = jax.random.PRNGKey(cfg.seed + 2)
    Xtr, ytr = data["X_train"], data["y_train"]
    m = num_teachers or cfg.num_parties
    rng = np.random.default_rng(cfg.seed)
    perm = rng.permutation(len(Xtr))
    states = []
    for sub in np.array_split(perm, m):
        key, kk = jax.random.split(key)
        states.append(learner.fit(kk, Xtr[sub], ytr[sub]))
    preds = jnp.stack([learner.predict(st, data["X_public"])
                       for st in states])
    vote = teacher_vote(preds, cfg.num_classes)
    key, kk = jax.random.split(key)
    st = learner.fit(kk, data["X_public"], np.asarray(vote.labels))
    return accuracy(learner, st, data["X_test"], data["y_test"])
