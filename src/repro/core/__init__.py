from repro.core.fedkt import (  # noqa: F401
    FedKTResult, run_fedkt, run_pate_central, run_solo,
)
from repro.core.voting import consistent_vote, teacher_vote  # noqa: F401
