from repro.core.voting import consistent_vote, teacher_vote  # noqa: F401
