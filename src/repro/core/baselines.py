"""Iterative federated baselines the paper compares against:
FedAvg, FedProx (proximal term), SCAFFOLD (control variates, option II),
plus FedKT-Prox (FedKT as initialization for FedProx — paper §5.2).

Local solvers follow the paper's setup: Adam(lr) for FedAvg/FedProx,
SGD for SCAFFOLD (control-variate correction assumes SGD steps).

This module holds the jit-compiled local solvers; the round
orchestration lives in ``repro.federation.strategies.IterativeStrategy``
(``run_iterative`` below is a deprecated wrapper over it).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, prox_grads


@dataclass(frozen=True)
class IterConfig:
    algo: str = "fedavg"          # fedavg | fedprox | scaffold
    rounds: int = 50
    local_steps: int = 100        # ~ local_epochs * n_batches
    lr: float = 1e-3
    batch_size: int = 32
    mu: float = 0.1               # fedprox proximal weight
    seed: int = 0


def _ce(net, p, xb, yb):
    logp = jax.nn.log_softmax(net.apply(p, xb))
    return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))


@functools.partial(jax.jit, static_argnums=(0, 1))
def _local_adam(net, icfg: IterConfig, key, global_params, X, y, mask):
    opt = adamw()
    state = opt.init(global_params)
    p_sel = mask / mask.sum()

    def step(carry, k):
        params, state = carry
        idx = jax.random.choice(k, X.shape[0], (icfg.batch_size,), p=p_sel)
        g = jax.grad(lambda p: _ce(net, p, X[idx], y[idx]))(params)
        if icfg.algo == "fedprox":
            g = prox_grads(g, params, global_params, icfg.mu)
        params, state = opt.update(g, state, params, icfg.lr)
        return (params, state), None

    keys = jax.random.split(key, icfg.local_steps)
    (params, _), _ = jax.lax.scan(step, (global_params, state), keys)
    return params


@functools.partial(jax.jit, static_argnums=(0, 1))
def _local_scaffold(net, icfg: IterConfig, key, global_params, X, y, mask,
                    c_global, c_i):
    p_sel = mask / mask.sum()

    def step(params, k):
        idx = jax.random.choice(k, X.shape[0], (icfg.batch_size,), p=p_sel)
        g = jax.grad(lambda p: _ce(net, p, X[idx], y[idx]))(params)
        params = jax.tree.map(
            lambda p, gg, cg, ci: p - icfg.lr * (gg - ci + cg),
            params, g, c_global, c_i)
        return params, None

    keys = jax.random.split(key, icfg.local_steps)
    params, _ = jax.lax.scan(step, global_params, keys)
    # option II control-variate update
    K_eta = icfg.local_steps * icfg.lr
    c_i_new = jax.tree.map(
        lambda ci, cg, xg, yi: ci - cg + (xg - yi) / K_eta,
        c_i, c_global, global_params, params)
    return params, c_i_new


def _wavg(trees: List[Any], weights: np.ndarray):
    w = jnp.asarray(weights / weights.sum(), jnp.float32)
    return jax.tree.map(
        lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *trees)


def run_iterative(net, data: Dict[str, np.ndarray], icfg: IterConfig, *,
                  num_parties=10, beta=0.5, party_indices=None,
                  init_params=None, eval_every=1) -> Dict[str, Any]:
    """Deprecated wrapper over ``IterativeStrategy``.  Returns
    {"acc_per_round", "params"}."""
    import warnings

    from repro.configs.base import FedKTConfig
    from repro.federation.strategies import IterativeStrategy

    warnings.warn("run_iterative is deprecated; use "
                  "repro.federation.IterativeStrategy instead",
                  DeprecationWarning, stacklevel=2)
    cfg = FedKTConfig(num_parties=num_parties, beta=beta, seed=icfg.seed)
    res = IterativeStrategy(net, icfg, init_params=init_params,
                            eval_every=eval_every).run(
        data, cfg, party_indices=party_indices)
    return {"acc_per_round": res.meta["acc_per_round"],
            "params": res.state}
