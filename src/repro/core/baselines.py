"""Iterative federated baselines the paper compares against:
FedAvg, FedProx (proximal term), SCAFFOLD (control variates, option II),
plus FedKT-Prox (FedKT as initialization for FedProx — paper §5.2).

Local solvers follow the paper's setup: Adam(lr) for FedAvg/FedProx,
SGD for SCAFFOLD (control-variate correction assumes SGD steps).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.learners import _pad_pow2
from repro.core.partition import dirichlet_partition
from repro.optim import adamw, prox_grads


@dataclass(frozen=True)
class IterConfig:
    algo: str = "fedavg"          # fedavg | fedprox | scaffold
    rounds: int = 50
    local_steps: int = 100        # ~ local_epochs * n_batches
    lr: float = 1e-3
    batch_size: int = 32
    mu: float = 0.1               # fedprox proximal weight
    seed: int = 0


def _ce(net, p, xb, yb):
    logp = jax.nn.log_softmax(net.apply(p, xb))
    return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))


@functools.partial(jax.jit, static_argnums=(0, 1))
def _local_adam(net, icfg: IterConfig, key, global_params, X, y, mask):
    opt = adamw()
    state = opt.init(global_params)
    p_sel = mask / mask.sum()

    def step(carry, k):
        params, state = carry
        idx = jax.random.choice(k, X.shape[0], (icfg.batch_size,), p=p_sel)
        g = jax.grad(lambda p: _ce(net, p, X[idx], y[idx]))(params)
        if icfg.algo == "fedprox":
            g = prox_grads(g, params, global_params, icfg.mu)
        params, state = opt.update(g, state, params, icfg.lr)
        return (params, state), None

    keys = jax.random.split(key, icfg.local_steps)
    (params, _), _ = jax.lax.scan(step, (global_params, state), keys)
    return params


@functools.partial(jax.jit, static_argnums=(0, 1))
def _local_scaffold(net, icfg: IterConfig, key, global_params, X, y, mask,
                    c_global, c_i):
    p_sel = mask / mask.sum()

    def step(params, k):
        idx = jax.random.choice(k, X.shape[0], (icfg.batch_size,), p=p_sel)
        g = jax.grad(lambda p: _ce(net, p, X[idx], y[idx]))(params)
        params = jax.tree.map(
            lambda p, gg, cg, ci: p - icfg.lr * (gg - ci + cg),
            params, g, c_global, c_i)
        return params, None

    keys = jax.random.split(key, icfg.local_steps)
    params, _ = jax.lax.scan(step, global_params, keys)
    # option II control-variate update
    K_eta = icfg.local_steps * icfg.lr
    c_i_new = jax.tree.map(
        lambda ci, cg, xg, yi: ci - cg + (xg - yi) / K_eta,
        c_i, c_global, global_params, params)
    return params, c_i_new


def _wavg(trees: List[Any], weights: np.ndarray):
    w = jnp.asarray(weights / weights.sum(), jnp.float32)
    return jax.tree.map(
        lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *trees)


def run_iterative(net, data: Dict[str, np.ndarray], icfg: IterConfig, *,
                  num_parties=10, beta=0.5, party_indices=None,
                  init_params=None, eval_every=1) -> Dict[str, Any]:
    """Runs FedAvg/FedProx/SCAFFOLD.  Returns {"acc_per_round", "params"}."""
    key = jax.random.PRNGKey(icfg.seed + 3)
    Xtr, ytr = data["X_train"], data["y_train"]
    if party_indices is None:
        party_indices = dirichlet_partition(ytr, num_parties, beta,
                                            icfg.seed)
    padded = [
        _pad_pow2(Xtr[ix], ytr[ix]) for ix in party_indices]
    sizes = np.array([len(ix) for ix in party_indices], np.float64)

    key, kk = jax.random.split(key)
    g_params = init_params if init_params is not None else net.init(kk)
    if icfg.algo == "scaffold":
        zeros = jax.tree.map(jnp.zeros_like, g_params)
        c_global = zeros
        c_parties = [zeros] * len(party_indices)

    Xte, yte = jnp.asarray(data["X_test"]), np.asarray(data["y_test"])
    accs = []
    for r in range(icfg.rounds):
        locals_, new_cs = [], []
        for i, (Xp, yp, mask) in enumerate(padded):
            key, kk = jax.random.split(key)
            if icfg.algo == "scaffold":
                p_i, c_i = _local_scaffold(net, icfg, kk, g_params, Xp, yp,
                                           mask, c_global, c_parties[i])
                new_cs.append(c_i)
            else:
                p_i = _local_adam(net, icfg, kk, g_params, Xp, yp, mask)
            locals_.append(p_i)
        g_params = _wavg(locals_, sizes)
        if icfg.algo == "scaffold":
            delta = [jax.tree.map(lambda a, b: a - b, cn, co)
                     for cn, co in zip(new_cs, c_parties)]
            c_parties = new_cs
            c_global = jax.tree.map(
                lambda cg, *ds: cg + sum(ds) / len(party_indices),
                c_global, *delta)
        if (r + 1) % eval_every == 0:
            preds = np.asarray(
                jnp.argmax(net.apply(g_params, Xte), -1))
            accs.append(float((preds == yte).mean()))
    return {"acc_per_round": accs, "params": g_params}
