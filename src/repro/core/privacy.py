"""Data-dependent privacy accounting for FedKT (paper §4 + Appendix A).

Implements:
  - Lemma 7   : q >= Pr[M(d) != o*] bound from the clean vote gaps
  - Thm 5/6   : per-query moment bounds for a (2*g, 0)-DP mechanism
  - Thm 1/2   : FedKT-L1 party-level accounting  (sensitivity 2s)
  - Thm 3/4   : FedKT-L2 example-level accounting (sensitivity 2),
                parallel composition across parties (max_i eps_i)
  - Thm 8     : composability across queries + tail-bound conversion to
                (eps, delta)
  - advanced composition (Dwork et al.) for the paper's §B.7 comparison
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

LAMBDAS = np.arange(1, 129, dtype=np.float64)


def lemma7_q(gaps: np.ndarray, gamma: float,
             num_classes: int) -> np.ndarray:
    """Per-query bound on q = Pr[M(d) != o*].

    gaps: (T,) top1-top2 clean vote gap per query.  The exact lemma sums
    over all o != o*; with only the top-2 gap available we use the valid
    upper bound (u-1) terms at the smallest gap.  Clipped to [0, 1].
    """
    g = np.maximum(np.asarray(gaps, np.float64), 0.0)
    per = (2.0 + gamma * g) / (4.0 * np.exp(gamma * g))
    return np.clip((num_classes - 1) * per, 0.0, 1.0)


def lemma7_q_exact(counts: np.ndarray, gamma: float) -> np.ndarray:
    """Exact Lemma-7 bound given full clean histograms (T, U)."""
    c = np.asarray(counts, np.float64)
    vmax = c.max(axis=1, keepdims=True)
    gaps = vmax - c                                  # (T, U), 0 at o*
    term = (2.0 + gamma * gaps) / (4.0 * np.exp(gamma * gaps))
    # zero out the o* term (gap==0 col contributes where c==vmax once)
    is_star = (c == vmax)
    # ensure only one argmax column removed per row
    first_star = np.cumsum(is_star, axis=1) == 1
    star = is_star & first_star
    q = term.sum(axis=1) - term[star].reshape(len(c), -1)[:, 0]
    return np.clip(q, 0.0, 1.0)


def per_query_moments(q: np.ndarray, eps0: float,
                      lambdas: np.ndarray = LAMBDAS) -> np.ndarray:
    """Thm 2/3 (via Thm 5+6): alpha(lambda) per query for a (eps0, 0)-DP
    mechanism with outcome-stability bound q.  Returns (T, L)."""
    q = np.asarray(q, np.float64)[:, None]
    lam = lambdas[None, :]
    # Theorem 5 bound: eps0 = 2*g  =>  2 g^2 l(l+1) = eps0^2/2 * l(l+1)
    bound_dd = (eps0 ** 2 / 2.0) * lam * (lam + 1.0)
    # Theorem 6 bound (valid when q < (e^eps0 - 1)/(e^{2 eps0} - 1))
    valid = q < (np.exp(eps0) - 1.0) / (np.exp(2.0 * eps0) - 1.0)
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        ratio = (1.0 - q) / (1.0 - np.exp(eps0) * q)
        t6 = np.log((1.0 - q) * ratio ** lam + q * np.exp(eps0 * lam))
    t6 = np.where(valid & np.isfinite(t6), t6, np.inf)
    return np.minimum(t6, bound_dd)


def moments_to_eps(alpha_total: np.ndarray, delta: float,
                   lambdas: np.ndarray = LAMBDAS) -> float:
    """Thm 8 tail bound: eps = min_l (alpha(l) + log(1/delta)) / l."""
    return float(np.min((alpha_total + np.log(1.0 / delta)) / lambdas))


def fedkt_l1_epsilon(gaps_or_counts, gamma: float, s: int,
                     num_classes: int, delta: float = 1e-5,
                     exact: bool = False) -> float:
    """Party-level eps of FedKT-L1 over the answered queries (Thm 1+2).

    The server mechanism is (2*s*gamma, 0) party-level DP per query.

    Lemma 7's q bound is evaluated on the RAW consistent-vote histogram
    with the raw noise scale: the server adds Lap(1/gamma) to counts
    that move in multiples of s, and q = Pr[noisy argmax != o*] only
    ever sees the products gamma * gap, which are invariant under
    rescaling counts and noise to "party units" (gap/s with Lap(1/(s*
    gamma))).  Party-level sensitivity enters ONLY through eps0 =
    2*s*gamma in the moment bound below — dividing the gaps by s as
    well would double-count s and loosen the bound.
    """
    if exact:
        q = lemma7_q_exact(gaps_or_counts, gamma)
    else:
        q = lemma7_q(gaps_or_counts, gamma, num_classes)
    alpha = per_query_moments(q, 2.0 * s * gamma).sum(axis=0)
    return moments_to_eps(alpha, delta)


def fedkt_l2_epsilon(per_party_gaps: Sequence[np.ndarray], gamma: float,
                     num_classes: int, delta: float = 1e-5) -> float:
    """Example-level eps of FedKT-L2 (Thm 3 per partition query set,
    Thm 4 parallel composition: max over parties).

    per_party_gaps: list over parties; each entry is the concatenated
    top-2 gaps of every query answered by that party's partitions.
    """
    eps_parties = []
    for gaps in per_party_gaps:
        if len(gaps) == 0:
            eps_parties.append(0.0)
            continue
        q = lemma7_q(np.asarray(gaps), gamma, num_classes)
        alpha = per_query_moments(q, 2.0 * gamma).sum(axis=0)
        eps_parties.append(moments_to_eps(alpha, delta))
    return float(max(eps_parties))


def advanced_composition(eps0: float, k: int, delta_slack: float) -> float:
    """(Dwork et al. 2014) k-fold advanced composition of an eps0-DP
    mechanism — the looser bound the paper compares against in §B.7."""
    return float(np.sqrt(2.0 * k * np.log(1.0 / delta_slack)) * eps0
                 + k * eps0 * (np.exp(eps0) - 1.0))
