"""Vote aggregation: the paper's Algorithm 1 math.

Party side  (lines 6-11): per-partition teacher ensemble max-vote, with
optional L2 Laplace noise on the histogram.
Server side (lines 14-22): consistent voting over the n*s student models
(v_m(x) = s * |{i : v^i_m(x) = s}|), with optional L1 Laplace noise.

Vote counting runs through kernels/ops.votes (Pallas on TPU); this module
adds the federation semantics, the on-device Laplace mechanism, and the
vote-gap bookkeeping the privacy accountant needs (Lemma 7).

Layout contract: the server-side functions (``party_vote_counts``,
``finalize_vote``, ``token_teacher_vote``) take a ``VoteDomain``
(federation/domain.py) — the typed (unit, T, U, query-fingerprint)
contract — instead of a bare class count, and a ``VoteResult`` carries
the domain it was computed in.  The party-side ``teacher_vote`` keeps
its integer ``num_classes`` (a within-party ensemble vote has no
cross-party contract to enforce), as does the batch ``consistent_vote``
convenience wrapper, which derives an anonymous example domain from its
inputs.  Duck typing keeps this module free of federation imports: a
domain here is anything with ``num_classes`` (and the attach-to-result
field).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


class VoteResult(NamedTuple):
    labels: jnp.ndarray       # (T,) int32
    counts: Optional[jnp.ndarray]  # (T, U) CLEAN counts (None on the TPU
    #                           kernel path, which never materializes the
    #                           histogram — it emits the gap directly)
    top_gap: jnp.ndarray      # (T,) f32 — clean top1 - top2 (Lemma 7)
    domain: Optional[Any] = None   # VoteDomain the vote was computed in
    #                           (None on party-internal ensemble votes)


def laplace(key, shape, scale):
    """Laplace(0, scale) via inverse CDF of uniform (on-device, counter-
    based PRNG — DESIGN.md §3).  The uniform is clipped to the SYMMETRIC
    interval [-0.5 + 1e-7, 0.5 - 1e-7] before the transform: clipping
    only the negative side (the old minval=-0.5+1e-7, maxval=0.5 draw)
    truncated the negative tail one ulp-band short of the positive one,
    biasing the DP noise toward positive values."""
    u = jax.random.uniform(key, shape, minval=-0.5, maxval=0.5)
    u = jnp.clip(u, -0.5 + 1e-7, 0.5 - 1e-7)
    return -scale * jnp.sign(u) * jnp.log1p(-2.0 * jnp.abs(u))


def teacher_vote(preds, num_classes, *, gamma=0.0, key=None,
                 impl="auto") -> VoteResult:
    """Party-side ensemble vote.  preds: (t, T) int32 teacher predictions.

    gamma > 0 adds Lap(1/gamma) to the histogram (FedKT-L2, lines 9-10).
    The noised labels and the clean Lemma-7 gap both come out of ONE
    histogram build (ops.votes_with_clean) — this runs once per
    partition per party, on the hot path of every round.
    """
    t, T = preds.shape
    noise = None
    if gamma > 0.0:
        assert key is not None
        noise = laplace(key, (T, num_classes), 1.0 / gamma)
    labels, counts, c1, c2 = ops.votes_with_clean(preds, num_classes,
                                                  noise, impl=impl)
    return VoteResult(labels, counts, c1 - c2)


def party_vote_counts(student_preds, domain, *,
                      consistent=True) -> jnp.ndarray:
    """ONE party's additive contribution to the server vote histogram.

    student_preds: (s, T) int32 — the party's s student predictions.
    domain: the VoteDomain the votes live in (U = domain.num_classes).
    Returns (T, U) int32.  Under consistent voting the party contributes
    s votes for class m iff all its s students predict m; otherwise each
    student votes independently.  The full server histogram is the plain
    integer SUM of these terms over parties, so a streaming aggregator
    (federation/aggregate.py) folding one update at a time produces
    counts bit-identical to the all-at-once ``consistent_vote`` — in any
    arrival order.
    """
    s, T = student_preds.shape
    if consistent:
        first = student_preds[0]                          # (T,)
        agree = jnp.all(student_preds == first[None], axis=0)     # (T,)
        onehot = jax.nn.one_hot(first, domain.num_classes,
                                dtype=jnp.int32)
        return s * onehot * agree[:, None].astype(jnp.int32)      # (T, U)
    _, counts = ref.vote_aggregate_ref(student_preds, domain.num_classes)
    return counts


def finalize_vote(counts, domain=None, *, gamma=0.0, key=None
                  ) -> VoteResult:
    """Noise + argmax + clean-gap bookkeeping over a finished server
    histogram (the second half of ``consistent_vote``, shared with the
    streaming aggregator).  counts: (T, U) int32 CLEAN counts — the
    histogram's own shape IS the layout, so the domain is attached to
    the result rather than re-plumbed through the math."""
    scores = counts.astype(jnp.float32)
    if gamma > 0.0:
        assert key is not None
        scores = scores + laplace(key, counts.shape, 1.0 / gamma)
    labels = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    top2 = jax.lax.top_k(counts.astype(jnp.float32), 2)[0]
    return VoteResult(labels, counts, top2[:, 0] - top2[:, 1],
                      domain=domain)


def consistent_vote(student_preds, num_classes, *, consistent=True,
                    gamma=0.0, key=None, impl="auto") -> VoteResult:
    """Server-side vote.  student_preds: (n, s, T) int32.

    consistent=True implements the paper's consistent voting: a party
    contributes s votes for class m iff all its s students predict m.
    gamma > 0 adds Lap(1/gamma) (FedKT-L1, lines 20-21).

    Implemented as the sum of per-party ``party_vote_counts`` terms so
    the batch path and the streaming fold (federation/aggregate.py) are
    the same integer arithmetic.  The batch convenience keeps its
    integer ``num_classes`` signature and derives an anonymous example
    domain from its inputs (no query set in sight here).
    """
    from repro.federation.domain import VoteDomain
    domain = VoteDomain(unit="example",
                        num_units=int(student_preds.shape[-1]),
                        num_classes=int(num_classes))
    counts = jnp.sum(
        jax.vmap(lambda sp: party_vote_counts(
            sp, domain, consistent=consistent))(student_preds),
        axis=0)                                           # (T, U)
    return finalize_vote(counts, domain, gamma=gamma, key=key)


def token_teacher_vote(preds_bts, domain, *, gamma=0.0, key=None,
                       impl="auto"):
    """LM-scale party-side vote: preds (M, B, S) over a vocab-sized class
    space (U = domain.num_classes).  Uses the blocked kernel path;
    returns (labels (B,S), gap).

    The gap is the CLEAN (pre-noise) top1 - top2, like ``teacher_vote``:
    Lemma 7's accountant needs the noise-free margin, and the LM path
    must feed the L2 bound the same quantity as every other mode
    (engine-parity is test-enforced in tests/test_federation_lm.py).
    """
    M, B, S = preds_bts.shape
    vocab = domain.num_classes
    flat = preds_bts.reshape(M, B * S)
    noise = None
    if gamma > 0.0:
        assert key is not None
        noise = laplace(key, (B * S, vocab), 1.0 / gamma)
    labels, _, c1, c2 = ops.votes_with_clean(flat, vocab, noise,
                                             impl=impl)
    return labels.reshape(B, S), (c1 - c2).reshape(B, S)
