"""Uniform Learner interface: anything with fit/predict can be a FedKT
teacher, student, or final model — differentiable or not.

NNLearner : jit-compiled Adam training loop over a smallnet (MLP / CNN /
            VGG).  Data is padded to power-of-two buckets so party/subset
            size variation doesn't retrigger compilation.
RFLearner / GBDTLearner : the JAX histogram tree learners (trees.py).
LMLearner : a full transformer-family Model behind the same contract —
            examples are (N, S+1) token sequences, "classes" are vocab
            ids, and a prediction is one vocab id per TOKEN (the flat
            (N*S,) layout every vote op already uses).  Wraps the
            sharded distill.py steps, so the federation session drives
            LM distillation through the exact code path launch/train.py
            and the fedkt_dryrun lower at datacenter scale.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import trees as T
from repro.optim import adamw


def _pow2_bucket(n, min_size=32):
    return max(min_size, 1 << (n - 1).bit_length())


def _mask_cols(X, mask):
    """Selects a party's feature columns (vertical federation: each
    silo holds a slice of the feature space).  ``mask`` is a tuple of
    column indices — a TUPLE, not an array, because the learners are
    frozen dataclasses used as jit static arguments and every field
    must hash.  None = all columns (the horizontal default)."""
    if mask is None:
        return np.asarray(X)
    return np.asarray(X)[:, list(mask)]


def _pad_pow2(X, y, min_size=32, bucket=None):
    n = len(X)
    m = bucket or _pow2_bucket(n, min_size)
    mask = np.zeros((m,), np.float32)
    mask[:n] = 1.0
    Xp = np.zeros((m,) + X.shape[1:], X.dtype)
    Xp[:n] = X
    yp = np.zeros((m,), np.int32)
    yp[:n] = y
    return jnp.asarray(Xp), jnp.asarray(yp), jnp.asarray(mask)


@dataclass(frozen=True)
class NNLearner:
    net: Any                      # smallnets module object (init/apply)
    num_classes: int
    steps: int = 300
    batch_size: int = 64
    lr: float = 1e-3
    l2: float = 1e-6
    # vertical federation: this party trains and predicts on only these
    # feature columns of any X it is handed (core.partition.
    # vertical_split); the net must be sized to len(feature_mask)
    feature_mask: Any = None      # Optional[Tuple[int, ...]]

    def _fit_body(self, key, X, y, mask):
        opt = adamw(weight_decay=self.l2)
        params = self.net.init(jax.random.fold_in(key, 1))
        state = opt.init(params)
        p_sel = mask / mask.sum()

        def loss_fn(p, xb, yb):
            logits = self.net.apply(p, xb)
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(
                jnp.take_along_axis(logp, yb[:, None], axis=1))

        def step(carry, k):
            params, state = carry
            idx = jax.random.choice(k, X.shape[0], (self.batch_size,),
                                    p=p_sel)
            g = jax.grad(loss_fn)(params, X[idx], y[idx])
            params, state = opt.update(g, state, params, self.lr)
            return (params, state), None

        keys = jax.random.split(jax.random.fold_in(key, 2), self.steps)
        (params, _), _ = jax.lax.scan(step, (params, state), keys)
        return params

    @functools.partial(jax.jit, static_argnums=0)
    def _fit(self, key, X, y, mask):
        return self._fit_body(key, X, y, mask)

    @functools.partial(jax.jit, static_argnums=0)
    def _fit_stacked(self, keys, X, y, mask):
        return jax.vmap(self._fit_body)(keys, X, y, mask)

    def fit(self, key, X, y):
        Xp, yp, mask = _pad_pow2(_mask_cols(X, self.feature_mask),
                                 np.asarray(y))
        return self._fit(key, Xp, yp, mask)

    def fit_stacked(self, keys, Xs, ys):
        """Trains len(Xs) models as ONE vmap'd fit (federation vmap
        engine).  All datasets share the largest member's pow2 bucket;
        per-row masks keep each model's sampling distribution on its own
        examples, so a model trained here matches its serial ``fit``
        whenever its individual bucket equals the shared one."""
        bucket = max(_pow2_bucket(len(X)) for X in Xs)
        padded = [_pad_pow2(_mask_cols(X, self.feature_mask),
                            np.asarray(y), bucket=bucket)
                  for X, y in zip(Xs, ys)]
        Xp, yp, mask = (jnp.stack([p[i] for p in padded])
                        for i in range(3))
        return self._fit_stacked(jnp.asarray(keys), Xp, yp, mask)

    def _predict_body(self, state, X):
        return jnp.argmax(self.net.apply(state, X), -1).astype(jnp.int32)

    @functools.partial(jax.jit, static_argnums=0)
    def _predict(self, state, X):
        return self._predict_body(state, X)

    def predict(self, state, X):
        return self._predict(state,
                             jnp.asarray(_mask_cols(X, self.feature_mask)))

    @functools.partial(jax.jit, static_argnums=0)
    def _predict_stacked(self, states, X):
        return jax.vmap(lambda st: self._predict_body(st, X))(states)

    def predict_stacked(self, states, X):
        """(k, T) predictions of k stacked models on one shared X."""
        return self._predict_stacked(
            states, jnp.asarray(_mask_cols(X, self.feature_mask)))


@dataclass(frozen=True)
class RFLearner:
    num_classes: int
    num_trees: int = 20
    depth: int = 6
    impl: str = "auto"            # ops.tree_hist backend knob
    feature_mask: Any = None      # vertical: this silo's columns

    def _rf(self):
        return T.RandomForest(self.num_trees, self.depth, self.num_classes,
                              impl=self.impl)

    def fit(self, key, X, y):
        X = _mask_cols(X, self.feature_mask).astype(np.float32)
        edges = jnp.asarray(T.make_bins(X))
        forest = self._rf().fit(key, jnp.asarray(X),
                                jnp.asarray(y, jnp.int32), edges)
        return (forest, edges)

    def fit_stacked(self, keys, Xs, ys):
        """k forests as one stacked jit fit (federation vmap engine).

        Each dataset keeps its own quantile edges and a bootstrap draw
        at its TRUE size (key-for-key identical to serial ``fit``); rows
        padding up to the shared pow2 bucket carry ZERO sample weight,
        so the stacked states are bit-identical to the serial loop
        regardless of bucket size (histograms ignore w == 0 rows)."""
        rf = self._rf()
        bucket = max(_pow2_bucket(len(X)) for X in Xs)
        edges, Xp, yp, wp, fm = [], [], [], [], []
        for kk, X, y in zip(keys, Xs, ys):
            X = _mask_cols(X, self.feature_mask).astype(np.float32)
            edges.append(T.make_bins(X))
            w_i, fm_i = rf.bootstrap(kk, len(X), X.shape[1])
            w_pad = np.zeros((self.num_trees, bucket), np.float32)
            w_pad[:, :len(X)] = np.asarray(w_i)
            Xi, yi, _ = _pad_pow2(X, np.asarray(y), bucket=bucket)
            Xp.append(Xi), yp.append(yi), wp.append(w_pad), fm.append(fm_i)
        edges = jnp.asarray(np.stack(edges))
        forest = T.fit_forest_stacked(
            jnp.stack(Xp), edges, jnp.stack(yp),
            jnp.asarray(np.stack(wp)), jnp.stack(fm),
            depth=self.depth, num_classes=self.num_classes,
            impl=self.impl)
        return (forest, edges)

    def predict(self, state, X):
        forest, edges = state
        X = _mask_cols(X, self.feature_mask)
        return self._rf().predict(forest, jnp.asarray(X, jnp.float32),
                                  edges)

    def predict_stacked(self, states, X):
        """(k, T) predictions of k stacked forests on one shared X."""
        forest, edges = states
        X = _mask_cols(X, self.feature_mask)
        return T.predict_forest_stacked(forest,
                                        jnp.asarray(X, jnp.float32), edges)


@dataclass(frozen=True)
class GBDTLearner:
    num_classes: int = 2
    num_rounds: int = 30
    depth: int = 6
    impl: str = "auto"            # ops.tree_hist backend knob
    feature_mask: Any = None      # vertical: this silo's columns

    def _gb(self):
        return T.GBDT(self.num_rounds, self.depth, impl=self.impl)

    def fit(self, key, X, y):
        X = _mask_cols(X, self.feature_mask).astype(np.float32)
        edges = jnp.asarray(T.make_bins(X))
        gb = self._gb()
        return (gb.fit(key, jnp.asarray(X), jnp.asarray(y, jnp.int32),
                       edges), edges)

    def fit_stacked(self, keys, Xs, ys):
        """k GBDTs as one stacked jit fit.  Shared pow2 bucket; padding
        rows carry zero g/h weight, so stacked == serial bit-for-bit
        (see trees.fit_gbdt)."""
        gb = self._gb()
        bucket = max(_pow2_bucket(len(X)) for X in Xs)
        edges, Xp, yp, wp = [], [], [], []
        for X, y in zip(Xs, ys):
            X = _mask_cols(X, self.feature_mask).astype(np.float32)
            edges.append(T.make_bins(X))
            Xi, yi, mi = _pad_pow2(X, np.asarray(y), bucket=bucket)
            Xp.append(Xi), yp.append(yi), wp.append(mi)
        edges = jnp.asarray(np.stack(edges))
        trees = T.fit_gbdt_stacked(
            jnp.stack(Xp), edges, jnp.stack(yp), jnp.stack(wp),
            gb.learning_rate, num_rounds=self.num_rounds, depth=self.depth,
            impl=self.impl)
        return (trees, edges)

    def predict(self, state, X):
        trees, edges = state
        X = _mask_cols(X, self.feature_mask)
        return self._gb().predict(trees, jnp.asarray(X, np.float32), edges)

    def predict_stacked(self, states, X):
        """(k, T) predictions of k stacked GBDTs on one shared X."""
        trees, edges = states
        X = _mask_cols(X, self.feature_mask)
        return T.predict_gbdt_stacked(trees, jnp.asarray(X, np.float32),
                                      edges, self._gb().learning_rate)


@dataclass(frozen=True, eq=False)
class LMLearner:
    """Language model as a FedKT learner (the paper's "any
    classification model" claim at LM scale).

    X is an (N, S+1) int32 token matrix; ``fit`` dispatches on the label
    shape: per-sequence labels (size N — the partitioner's proxy classes)
    mean plain next-token training, per-token labels (size N*S — a vote
    answer) mean distillation on the given labels.  ``predict`` returns
    one vocab id per token, flattened to (N*S,), which is exactly the
    (t, T) layout ``teacher_vote``/``consistent_vote`` consume.

    PRNG contract: LM training randomness is owned by ``tcfg.seed``
    (init) and ``data_seed`` (the TokenDataset shuffle stream), matching
    launch/train.py's ``train_lm`` — the federation key a fit receives
    only feeds DP vote noise elsewhere in the protocol, so it is
    deliberately unused here and engine/transport fan-out cannot change
    a fit.  Construct with ``data_seed=cfg.seed`` for the student/final
    roles (the legacy loop shuffled the public stream with the federation
    seed) and the default 0 for teachers.
    """
    model: Any                    # models.Model
    tcfg: Any                     # configs.TrainConfig
    data_seed: int = 0            # TokenDataset shuffle seed

    # jitted-step caches live in __dict__ (cached_property); drop them on
    # pickle so Subprocess transports ship only the config fields
    def __getstate__(self):
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}

    def __setstate__(self, state):
        for k, v in state.items():
            object.__setattr__(self, k, v)

    @functools.cached_property
    def _train_machinery(self):
        from repro.core.distill import make_train_step
        step, opt = make_train_step(self.model, self.tcfg)
        return jax.jit(step), opt

    @functools.cached_property
    def _predict_jit(self):
        return jax.jit(
            lambda p, toks: self.model.predict(p, {"tokens": toks}))

    @functools.cached_property
    def _predict_stacked_jit(self):
        return jax.jit(jax.vmap(
            lambda p, toks: self.model.predict(p, {"tokens": toks}),
            in_axes=(0, None)))

    @functools.cached_property
    def _label_steps(self):
        return {}                 # (num_members, gamma) -> jitted step

    def _tokens(self, X):
        X = np.asarray(X)
        assert X.ndim == 2 and X.shape[1] >= 3, \
            "LMLearner expects (N, S+1) token sequences with S >= 2"
        return X.astype(np.int32)

    def fit(self, key, X, y=None):
        from repro.data.pipeline import TokenDataset
        X = self._tokens(X)
        N, S = X.shape[0], X.shape[1] - 1
        if N < self.tcfg.batch_size:
            raise ValueError(f"LMLearner.fit needs >= batch_size="
                             f"{self.tcfg.batch_size} sequences, got {N}")
        labels = None
        if y is not None:
            y = np.asarray(y)
            if y.size == N * S:               # voted token labels
                labels = y.reshape(N, S).astype(np.int32)
            elif y.size != N:                 # size N: proxy classes
                raise ValueError(f"labels of size {y.size} match neither "
                                 f"{N} sequences nor {N * S} tokens")
        step, opt = self._train_machinery
        params = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        opt_state = opt.init(params)
        ds = TokenDataset(X, self.data_seed)
        for batch in ds.batches(self.tcfg.batch_size,
                                steps=self.tcfg.steps, labels=labels):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, _ = step(params, opt_state, batch)
        return params

    def predict(self, state, X):
        toks = jnp.asarray(self._tokens(X)[:, :-1])
        return self._predict_jit(state, toks).reshape(-1)

    def predict_stacked(self, bank, X):
        """(M, N*S) predictions of M member-stacked param sets."""
        toks = jnp.asarray(self._tokens(X)[:, :-1])
        preds = self._predict_stacked_jit(bank, toks)
        return preds.reshape(preds.shape[0], -1)

    def vote_domain(self, Xq, default_num_classes: int, *,
                    fingerprint=None):
        """The LM path's vote layout, declared by the learner (the
        ``vote_domain`` hook — docs/engines.md "Vote domains"): one
        vote row per query TOKEN (T = N*S over an (N, S+1) query
        matrix) ranging over the model's own vocab, regardless of the
        session's default class count."""
        from repro.federation.domain import (fingerprint_queries,
                                             token_domain)
        X = self._tokens(Xq)
        if fingerprint is None:
            fingerprint = fingerprint_queries(np.asarray(Xq))
        return token_domain(X.shape[0] * (X.shape[1] - 1),
                            self.model.cfg.vocab_size,
                            fingerprint=fingerprint)

    def label_step(self, num_members: int, gamma: float = 0.0):
        """The raw distill.make_label_step fn over ``num_members``
        stacked param sets — the step fedkt_dryrun lowers onto the
        production mesh, exposed so the dry-run prices the session
        engine's exact computation."""
        from repro.core.distill import make_label_step
        return make_label_step(self.model, num_members, gamma=gamma)

    def vote_members(self, bank, X, *, gamma: float = 0.0, key=None):
        """Greedy-predict + token vote over a stacked member bank in ONE
        step (the cross-member reduction is the paper's single round at
        scale).  Returns (labels (N*S,), clean gaps (N*S,)) — identical
        bit-for-bit to serial per-member predicts + ``teacher_vote``
        (test-enforced)."""
        toks = jnp.asarray(self._tokens(X)[:, :-1])
        m = int(jax.tree.leaves(bank)[0].shape[0])
        ck = (m, float(gamma))
        if ck not in self._label_steps:
            self._label_steps[ck] = jax.jit(self.label_step(m, gamma))
        labels, gap = self._label_steps[ck](bank, {"tokens": toks}, key)
        return labels.reshape(-1), gap.reshape(-1)


def accuracy(learner, state, X, y) -> float:
    preds = np.asarray(learner.predict(state, X))
    return float((preds == np.asarray(y)).mean())
