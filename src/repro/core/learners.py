"""Uniform Learner interface: anything with fit/predict can be a FedKT
teacher, student, or final model — differentiable or not.

NNLearner : jit-compiled Adam training loop over a smallnet (MLP / CNN /
            VGG).  Data is padded to power-of-two buckets so party/subset
            size variation doesn't retrigger compilation.
RFLearner / GBDTLearner : the JAX histogram tree learners (trees.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import trees as T
from repro.optim import adamw


def _pow2_bucket(n, min_size=32):
    return max(min_size, 1 << (n - 1).bit_length())


def _pad_pow2(X, y, min_size=32, bucket=None):
    n = len(X)
    m = bucket or _pow2_bucket(n, min_size)
    mask = np.zeros((m,), np.float32)
    mask[:n] = 1.0
    Xp = np.zeros((m,) + X.shape[1:], X.dtype)
    Xp[:n] = X
    yp = np.zeros((m,), np.int32)
    yp[:n] = y
    return jnp.asarray(Xp), jnp.asarray(yp), jnp.asarray(mask)


@dataclass(frozen=True)
class NNLearner:
    net: Any                      # smallnets module object (init/apply)
    num_classes: int
    steps: int = 300
    batch_size: int = 64
    lr: float = 1e-3
    l2: float = 1e-6

    def _fit_body(self, key, X, y, mask):
        opt = adamw(weight_decay=self.l2)
        params = self.net.init(jax.random.fold_in(key, 1))
        state = opt.init(params)
        p_sel = mask / mask.sum()

        def loss_fn(p, xb, yb):
            logits = self.net.apply(p, xb)
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(
                jnp.take_along_axis(logp, yb[:, None], axis=1))

        def step(carry, k):
            params, state = carry
            idx = jax.random.choice(k, X.shape[0], (self.batch_size,),
                                    p=p_sel)
            g = jax.grad(loss_fn)(params, X[idx], y[idx])
            params, state = opt.update(g, state, params, self.lr)
            return (params, state), None

        keys = jax.random.split(jax.random.fold_in(key, 2), self.steps)
        (params, _), _ = jax.lax.scan(step, (params, state), keys)
        return params

    @functools.partial(jax.jit, static_argnums=0)
    def _fit(self, key, X, y, mask):
        return self._fit_body(key, X, y, mask)

    @functools.partial(jax.jit, static_argnums=0)
    def _fit_stacked(self, keys, X, y, mask):
        return jax.vmap(self._fit_body)(keys, X, y, mask)

    def fit(self, key, X, y):
        Xp, yp, mask = _pad_pow2(np.asarray(X), np.asarray(y))
        return self._fit(key, Xp, yp, mask)

    def fit_stacked(self, keys, Xs, ys):
        """Trains len(Xs) models as ONE vmap'd fit (federation vmap
        engine).  All datasets share the largest member's pow2 bucket;
        per-row masks keep each model's sampling distribution on its own
        examples, so a model trained here matches its serial ``fit``
        whenever its individual bucket equals the shared one."""
        bucket = max(_pow2_bucket(len(X)) for X in Xs)
        padded = [_pad_pow2(np.asarray(X), np.asarray(y), bucket=bucket)
                  for X, y in zip(Xs, ys)]
        Xp, yp, mask = (jnp.stack([p[i] for p in padded])
                        for i in range(3))
        return self._fit_stacked(jnp.asarray(keys), Xp, yp, mask)

    def _predict_body(self, state, X):
        return jnp.argmax(self.net.apply(state, X), -1).astype(jnp.int32)

    @functools.partial(jax.jit, static_argnums=0)
    def _predict(self, state, X):
        return self._predict_body(state, X)

    def predict(self, state, X):
        return self._predict(state, jnp.asarray(X))

    @functools.partial(jax.jit, static_argnums=0)
    def _predict_stacked(self, states, X):
        return jax.vmap(lambda st: self._predict_body(st, X))(states)

    def predict_stacked(self, states, X):
        """(k, T) predictions of k stacked models on one shared X."""
        return self._predict_stacked(states, jnp.asarray(X))


@dataclass(frozen=True)
class RFLearner:
    num_classes: int
    num_trees: int = 20
    depth: int = 6
    impl: str = "auto"            # ops.tree_hist backend knob

    def _rf(self):
        return T.RandomForest(self.num_trees, self.depth, self.num_classes,
                              impl=self.impl)

    def fit(self, key, X, y):
        X = np.asarray(X, np.float32)
        edges = jnp.asarray(T.make_bins(X))
        forest = self._rf().fit(key, jnp.asarray(X),
                                jnp.asarray(y, jnp.int32), edges)
        return (forest, edges)

    def fit_stacked(self, keys, Xs, ys):
        """k forests as one stacked jit fit (federation vmap engine).

        Each dataset keeps its own quantile edges and a bootstrap draw
        at its TRUE size (key-for-key identical to serial ``fit``); rows
        padding up to the shared pow2 bucket carry ZERO sample weight,
        so the stacked states are bit-identical to the serial loop
        regardless of bucket size (histograms ignore w == 0 rows)."""
        rf = self._rf()
        bucket = max(_pow2_bucket(len(X)) for X in Xs)
        edges, Xp, yp, wp, fm = [], [], [], [], []
        for kk, X, y in zip(keys, Xs, ys):
            X = np.asarray(X, np.float32)
            edges.append(T.make_bins(X))
            w_i, fm_i = rf.bootstrap(kk, len(X), X.shape[1])
            w_pad = np.zeros((self.num_trees, bucket), np.float32)
            w_pad[:, :len(X)] = np.asarray(w_i)
            Xi, yi, _ = _pad_pow2(X, np.asarray(y), bucket=bucket)
            Xp.append(Xi), yp.append(yi), wp.append(w_pad), fm.append(fm_i)
        edges = jnp.asarray(np.stack(edges))
        forest = T.fit_forest_stacked(
            jnp.stack(Xp), edges, jnp.stack(yp),
            jnp.asarray(np.stack(wp)), jnp.stack(fm),
            depth=self.depth, num_classes=self.num_classes,
            impl=self.impl)
        return (forest, edges)

    def predict(self, state, X):
        forest, edges = state
        return self._rf().predict(forest, jnp.asarray(X, jnp.float32),
                                  edges)

    def predict_stacked(self, states, X):
        """(k, T) predictions of k stacked forests on one shared X."""
        forest, edges = states
        return T.predict_forest_stacked(forest,
                                        jnp.asarray(X, jnp.float32), edges)


@dataclass(frozen=True)
class GBDTLearner:
    num_classes: int = 2
    num_rounds: int = 30
    depth: int = 6
    impl: str = "auto"            # ops.tree_hist backend knob

    def _gb(self):
        return T.GBDT(self.num_rounds, self.depth, impl=self.impl)

    def fit(self, key, X, y):
        X = np.asarray(X, np.float32)
        edges = jnp.asarray(T.make_bins(X))
        gb = self._gb()
        return (gb.fit(key, jnp.asarray(X), jnp.asarray(y, jnp.int32),
                       edges), edges)

    def fit_stacked(self, keys, Xs, ys):
        """k GBDTs as one stacked jit fit.  Shared pow2 bucket; padding
        rows carry zero g/h weight, so stacked == serial bit-for-bit
        (see trees.fit_gbdt)."""
        gb = self._gb()
        bucket = max(_pow2_bucket(len(X)) for X in Xs)
        edges, Xp, yp, wp = [], [], [], []
        for X, y in zip(Xs, ys):
            X = np.asarray(X, np.float32)
            edges.append(T.make_bins(X))
            Xi, yi, mi = _pad_pow2(X, np.asarray(y), bucket=bucket)
            Xp.append(Xi), yp.append(yi), wp.append(mi)
        edges = jnp.asarray(np.stack(edges))
        trees = T.fit_gbdt_stacked(
            jnp.stack(Xp), edges, jnp.stack(yp), jnp.stack(wp),
            gb.learning_rate, num_rounds=self.num_rounds, depth=self.depth,
            impl=self.impl)
        return (trees, edges)

    def predict(self, state, X):
        trees, edges = state
        return self._gb().predict(trees, jnp.asarray(X, np.float32), edges)

    def predict_stacked(self, states, X):
        """(k, T) predictions of k stacked GBDTs on one shared X."""
        trees, edges = states
        return T.predict_gbdt_stacked(trees, jnp.asarray(X, np.float32),
                                      edges, self._gb().learning_rate)


def accuracy(learner, state, X, y) -> float:
    preds = np.asarray(learner.predict(state, X))
    return float((preds == np.asarray(y)).mean())
