"""RecurrentGemma / Griffin recurrent block (RG-LRU).

Block structure (Griffin, arXiv:2402.19427):
    x -> W_in -> causal conv1d(width 4) -> RG-LRU -> (* gelu-gate branch)
      -> W_out
RG-LRU:
    r_t = sigmoid(W_a y_t);  i_t = sigmoid(W_x y_t)
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * y_t)
The scan itself runs through kernels/ops.rglru (Pallas on TPU,
associative scan on CPU).

Simplification vs the released model: the gate projections W_a / W_x are
dense rather than block-diagonal-per-head (DESIGN.md §10).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.kernels import ops


def init_rglru(cfg: ModelConfig, key):
    D = cfg.d_model
    W = cfg.rglru_conv_width
    ks = jax.random.split(key, 7)
    dt = cfg.param_dtype
    # init Lambda so that a^(c*softplus) starts in ~[0.9, 0.999]
    a0 = jax.random.uniform(ks[0], (D,), minval=0.9, maxval=0.999)
    z = -jnp.log(a0) / cfg.rglru_c
    lam = jnp.log(jnp.expm1(z))
    return {
        "w_in": dense_init(ks[1], (D, D), dt),
        "w_gate": dense_init(ks[2], (D, D), dt),
        "conv_w": (jax.random.normal(ks[3], (W, D)) * W ** -0.5).astype(dt),
        "conv_b": jnp.zeros((D,), dt),
        "w_a": dense_init(ks[4], (D, D), dt),
        "w_x": dense_init(ks[5], (D, D), dt),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[6], (D, D), dt),
    }


def init_rglru_state(cfg: ModelConfig, batch, dtype):
    D, W = cfg.d_model, cfg.rglru_conv_width
    return {
        "h": jnp.zeros((batch, D), jnp.float32),
        "conv": jnp.zeros((batch, W - 1, D), dtype),
    }


def _conv1d(p, x, state=None):
    """Causal depthwise conv, width W.  x: (B,S,D).  state: (B,W-1,D)."""
    W = p["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+W-1, D)
    y = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i].astype(x.dtype)
            for i in range(W))
    new_state = xp[:, -(W - 1):]
    return y + p["conv_b"].astype(x.dtype), new_state


def rglru_apply(cfg: ModelConfig, p, x, *, mode="train", state=None,
                impl="auto"):
    """x: (B, S, D).  Returns (y, new_state)."""
    B, S, D = x.shape
    gate_branch = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype))

    y = x @ p["w_in"].astype(x.dtype)
    conv_state = state["conv"] if state is not None else None
    y, new_conv = _conv1d(p, y, conv_state)

    yf = y.astype(jnp.float32)
    r = jax.nn.sigmoid(yf @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(yf @ p["w_x"].astype(jnp.float32))
    log_a = -cfg.rglru_c * jax.nn.softplus(p["lam"]) * r      # (B,S,D) < 0
    beta = jnp.sqrt(1.0 - jnp.exp(2.0 * log_a))
    gated_in = (beta * i * yf).astype(x.dtype)

    h0 = state["h"] if state is not None else None
    h, h_last = ops.rglru(gated_in, log_a.astype(x.dtype), h0, impl=impl)

    out = (h.astype(x.dtype) * gate_branch) @ p["w_out"].astype(x.dtype)
    new_state = None
    if mode in ("prefill", "decode"):
        new_state = {"h": h_last.astype(jnp.float32), "conv": new_conv}
    return out, new_state
