"""Mixture-of-experts FFN with capacity-based grouped dispatch.

TPU-native formulation (no dynamic shapes, no per-token scatter loops):

  1. router softmax -> top-k experts per token (renormalized gates)
  2. slot assignment: cumulative position of each (token, choice) within
     its expert, dropped beyond capacity C = ceil(T*k*cf/E)
  3. gather tokens into a dense (E, C, D) block -> batched expert matmuls
     (MXU-friendly einsum over stacked expert weights)
  4. weighted scatter-add back to (T, D)

Overflow slots are routed to a sacrificial C-th column so clipping can
never corrupt a real slot.  Under pjit the gather/scatter over the
token-sharded axis lowers to the expected all-to-all-style collectives —
this IS the MoE communication pattern, and it shows up in the roofline's
collective term.

DeepSeek-style shared experts run densely over all tokens and are added
to the routed output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import dense_init


def init_moe(cfg: ModelConfig, key):
    m: MoEConfig = cfg.moe
    E, D, F = m.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    dt = cfg.param_dtype
    gated = cfg.mlp in ("swiglu", "geglu")
    p = {
        "router": dense_init(ks[0], (D, E), dt, scale=0.02),
        "w_up": jax.random.normal(ks[1], (E, D, F)).astype(dt) * D ** -0.5,
        "w_down": jax.random.normal(ks[2], (E, F, D)).astype(dt) * F ** -0.5,
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[3], (E, D, F)).astype(dt)
                       * D ** -0.5)
    if m.num_shared_experts:
        Fs = m.num_shared_experts * F
        sp = {
            "w_up": dense_init(ks[4], (D, Fs), dt),
            "w_down": dense_init(ks[0], (Fs, D), dt),
        }
        if gated:
            sp["w_gate"] = dense_init(ks[1], (D, Fs), dt)
        p["shared"] = sp
    return p


def _act(cfg: ModelConfig, p, x, h_up):
    if cfg.mlp == "swiglu":
        return jax.nn.silu(x) * h_up
    if cfg.mlp == "geglu":
        return jax.nn.gelu(x) * h_up
    raise ValueError(cfg.mlp)


def moe_apply(cfg: ModelConfig, p, x):
    """x: (B, S, D).  Returns (y, aux_loss)."""
    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    T = B * S
    xf = x.reshape(T, D)
    gated = "w_gate" in p

    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    gate, idx = jax.lax.top_k(probs, K)                      # (T, K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # switch-style load-balance auxiliary loss
    me = probs.mean(0)                                       # (E,)
    ce = jax.nn.one_hot(idx[:, 0], E).mean(0)
    aux = m.router_aux_weight * E * jnp.sum(me * ce)

    # ---- slot assignment ----
    C = int(-(-T * K * m.capacity_factor // E))              # ceil
    flat_e = idx.reshape(T * K)                              # token-major
    flat_g = gate.reshape(T * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (TK, E)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=0), flat_e[:, None], axis=1)[:, 0] - 1
    valid = pos < C
    pos = jnp.where(valid, pos, C)                           # spill slot C
    tok = jnp.arange(T * K) // K

    slot_tok = jnp.zeros((E, C + 1), jnp.int32).at[flat_e, pos].set(tok)
    slot_gate = jnp.zeros((E, C + 1), jnp.float32).at[flat_e, pos].set(
        jnp.where(valid, flat_g, 0.0))
    slot_tok, slot_gate = slot_tok[:, :C], slot_gate[:, :C]

    # ---- expert compute ----
    x_grp = xf[slot_tok.reshape(-1)].reshape(E, C, D)        # (E, C, D)
    up = jnp.einsum("ecd,edf->ecf", x_grp, p["w_up"].astype(x.dtype))
    if gated:
        g = jnp.einsum("ecd,edf->ecf", x_grp, p["w_gate"].astype(x.dtype))
        h = _act(cfg, p, g, up)
    else:
        h = jax.nn.gelu(up)
    y_grp = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))

    # ---- combine ----
    y_flat = (y_grp * slot_gate[..., None].astype(x.dtype)).reshape(-1, D)
    y = jnp.zeros((T, D), x.dtype).at[slot_tok.reshape(-1)].add(y_flat)

    if "shared" in p:
        sp = p["shared"]
        s_up = xf @ sp["w_up"].astype(x.dtype)
        if gated:
            s_h = _act(cfg, sp, xf @ sp["w_gate"].astype(x.dtype), s_up)
        else:
            s_h = jax.nn.gelu(s_up)
        y = y + s_h @ sp["w_down"].astype(x.dtype)

    return y.reshape(B, S, D), aux


def moe_ref(cfg: ModelConfig, p, x):
    """Dense oracle: every expert on every token, exact top-k combine
    (no capacity drops).  Used by tests to bound the dispatch error."""
    m = cfg.moe
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, m.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    up = jnp.einsum("td,edf->tef", xf, p["w_up"].astype(x.dtype))
    if "w_gate" in p:
        g = jnp.einsum("td,edf->tef", xf, p["w_gate"].astype(x.dtype))
        h = _act(cfg, p, g, up)
    else:
        h = jax.nn.gelu(up)
    y_all = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(x.dtype))
    w = jnp.zeros(probs.shape, jnp.float32).at[
        jnp.arange(xf.shape[0])[:, None], idx].set(gate)
    y = jnp.einsum("ted,te->td", y_all.astype(jnp.float32), w)
    y = y.astype(x.dtype)
    if "shared" in p:
        sp = p["shared"]
        s_up = xf @ sp["w_up"].astype(x.dtype)
        if "w_gate" in sp:
            s_h = _act(cfg, sp, xf @ sp["w_gate"].astype(x.dtype), s_up)
        else:
            s_h = jax.nn.gelu(s_up)
        y = y + s_h @ sp["w_down"].astype(x.dtype)
    return y.reshape(B, S, D)
