"""Uniform model interface over the architecture zoo.

``Model`` bundles (init, hidden, loss, predict, init_cache) for one
ModelConfig, hiding the decoder-only vs encoder-decoder split and the
modality-frontend stubs.  Batches are plain dicts:

  tokens  (B, S)  int32      — always present
  labels  (B, S)  int32      — for loss()/distillation
  mask    (B, S)  f32        — optional loss mask
  embeds  (B, Se, D)         — VLM patch embeddings (llava stub)
  frames  (B, Sf, D)         — audio frame embeddings (whisper stub)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict


from repro.configs.base import ModelConfig
from repro.models import encdec, transformer


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- init ----
    def init(self, key):
        if self.cfg.is_encoder_decoder:
            return encdec.init_params(self.cfg, key)
        return transformer.init_params(self.cfg, key)

    # ---- forward to final hidden ----
    def hidden(self, params, batch: Dict[str, Any], *, mode="train",
               cache=None, pos=None, impl="auto", remat=True):
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            enc_out = None
            if "frames" in batch:
                enc_out = encdec.encode(cfg, params, batch["frames"],
                                        impl=impl)
            return encdec.decode_forward(
                cfg, params, batch["tokens"], enc_out, mode=mode,
                cache=cache, pos=pos, impl=impl, remat=remat)
        return transformer.forward(
            cfg, params, batch["tokens"], embeds=batch.get("embeds"),
            mode=mode, cache=cache, pos=pos, impl=impl, remat=remat)

    # ---- training / distillation loss ----
    def loss(self, params, batch, *, impl="auto", remat=True):
        cfg = self.cfg
        h, _, aux = self.hidden(params, batch, mode="train", impl=impl,
                                remat=remat)
        h = self._text_hidden(h, batch)
        ce = transformer.lm_loss(cfg, params, h, batch["labels"],
                                 batch.get("mask"))
        return ce + aux

    # ---- teacher vote: greedy per-token prediction ----
    def predict(self, params, batch, *, impl="auto"):
        h, _, _ = self.hidden(params, batch, mode="train", impl=impl,
                              remat=False)
        h = self._text_hidden(h, batch)
        return transformer.predict_argmax(self.cfg, params, h)

    def logits(self, params, batch, *, mode="train", cache=None, pos=None,
               impl="auto"):
        h, new_cache, _ = self.hidden(params, batch, mode=mode, cache=cache,
                                      pos=pos, impl=impl, remat=False)
        if mode == "train":
            h = self._text_hidden(h, batch)
        return transformer.logits_fn(self.cfg, params, h), new_cache

    # ---- serving: make room for more decode steps ----
    def grow_cache(self, cache, extra_tokens: int):
        """Returns ``cache`` with every self-attention KV buffer grown
        by ``extra_tokens`` slots along its tagged length dim (recurrent
        state and encoder cross-K/V pass through untouched)."""
        if self.cfg.is_encoder_decoder:
            return encdec.grow_cache(self.cfg, cache, extra_tokens)
        return transformer.grow_cache(self.cfg, cache, extra_tokens)

    # ---- serving: scatter a bucket-prefill into persistent slots ----
    def insert_cache(self, slot_cache, prefill_cache, slots, plens):
        """Writes each request of a padded-bucket prefill cache into its
        assigned row of the continuous-batching slot cache (see
        ``transformer.insert_cache``); decoder-only models only."""
        if self.cfg.is_encoder_decoder:
            raise NotImplementedError(
                "slot-cache serving is decoder-only")
        return transformer.insert_cache(self.cfg, slot_cache,
                                        prefill_cache, slots, plens)

    def _text_hidden(self, h, batch):
        """Drop frontend positions so hidden aligns with tokens/labels."""
        if "embeds" in batch and batch["embeds"] is not None:
            return h[:, batch["embeds"].shape[1]:]
        return h

    # ---- serving cache ----
    def init_cache(self, batch_size, cache_len, dtype=None,
                   enc_out=None, params=None):
        if self.cfg.is_encoder_decoder:
            return encdec.init_dec_cache(self.cfg, batch_size, cache_len,
                                         enc_out, params, dtype)
        return transformer.init_cache(self.cfg, batch_size, cache_len,
                                      dtype)


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
