"""RWKV-6 (Finch) block: time-mix (WKV recurrence) + channel-mix.

Faithful structure with one documented simplification: token-shift
interpolation uses static per-channel mix vectors (RWKV-5 style) rather
than the data-dependent ddlerp LoRA; the *decay* keeps its data-dependent
LoRA (w = exp(-exp(w0 + tanh(x W1) W2))), which is the Finch contribution
that matters for the recurrence (DESIGN.md §10).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.kernels import ops

_W_LORA = 64


def init_rwkv(cfg: ModelConfig, key):
    D = cfg.d_model
    H, dh = cfg.num_heads, cfg.rwkv_head_dim
    assert H * dh == D, (H, dh, D)
    ks = jax.random.split(key, 12)
    dt = cfg.param_dtype

    def mix(k):
        return jax.random.uniform(k, (D,)).astype(dt)

    return {
        # time-mix
        "mu_r": mix(ks[0]), "mu_k": mix(ks[1]), "mu_v": mix(ks[2]),
        "mu_w": mix(ks[3]), "mu_g": mix(ks[4]),
        "w_r": dense_init(ks[5], (D, D), dt),
        "w_k": dense_init(ks[6], (D, D), dt),
        "w_v": dense_init(ks[7], (D, D), dt),
        "w_g": dense_init(ks[8], (D, D), dt),
        "w_o": dense_init(ks[9], (D, D), dt),
        "w0": (jnp.zeros((D,)) - 0.6).astype(jnp.float32),
        "w_lora_a": dense_init(ks[10], (D, _W_LORA), dt, scale=0.01),
        "w_lora_b": dense_init(ks[11], (_W_LORA, D), dt, scale=0.01),
        "u": (jax.random.normal(ks[0], (H, dh)) * 0.1).astype(jnp.float32),
        "ln_scale": jnp.ones((H, dh), dt),
        # channel-mix
        "cm_mu_k": mix(ks[1]), "cm_mu_r": mix(ks[2]),
        "cm_w_r": dense_init(ks[3], (D, D), dt),
        "cm_w_up": dense_init(ks[4], (D, cfg.d_ff), dt),
        "cm_w_down": dense_init(ks[5], (cfg.d_ff, D), dt),
    }


def init_rwkv_state(cfg: ModelConfig, batch, dtype):
    D = cfg.d_model
    H, dh = cfg.num_heads, cfg.rwkv_head_dim
    return {
        "wkv": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "shift_t": jnp.zeros((batch, D), dtype),
        "shift_c": jnp.zeros((batch, D), dtype),
    }


def _token_shift(x, last):
    """Returns x_{t-1} (with ``last`` filling position 0) and new last."""
    prev = jnp.concatenate([last[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    return prev, x[:, -1]


def _lerp(x, prev, mu):
    return x + (prev - x) * mu.astype(x.dtype)


def rwkv_time_mix(cfg: ModelConfig, p, x, *, state=None, impl="auto"):
    from repro.sharding.specs import DP, constrain
    B, S, D = x.shape
    H, dh = cfg.num_heads, cfg.rwkv_head_dim
    # resolve the stream layout ONCE: the five lerp->matmul consumers all
    # need full-D x; without this GSPMD re-gathers each lerp output
    # (measured 23x 4.3GB f32 gathers per layer — §Perf iter 3)
    x = constrain(x, DP, None, None)
    last = state["shift_t"] if state is not None else jnp.zeros(
        (B, D), x.dtype)
    prev, new_last = _token_shift(x, last)

    r = _lerp(x, prev, p["mu_r"]) @ p["w_r"].astype(x.dtype)
    k = _lerp(x, prev, p["mu_k"]) @ p["w_k"].astype(x.dtype)
    v = _lerp(x, prev, p["mu_v"]) @ p["w_v"].astype(x.dtype)
    g = _lerp(x, prev, p["mu_g"]) @ p["w_g"].astype(x.dtype)
    xw = _lerp(x, prev, p["mu_w"])

    # data-dependent decay (Finch).  Matmuls stay in the compute dtype —
    # a f32 (B,S,D) decay path doubles the stream's collective traffic
    # (§Perf iter 4); only the elementwise double-exp runs in f32.
    dd = jnp.tanh(xw @ p["w_lora_a"].astype(x.dtype)) \
        @ p["w_lora_b"].astype(x.dtype)
    w = jnp.exp(-jnp.exp(p["w0"] + dd.astype(jnp.float32)))   # (B,S,D)

    from repro.sharding.specs import shard_heads
    shp = (B, S, H, dh)
    s0 = state["wkv"] if state is not None else None
    o, s_last = ops.wkv(shard_heads(r.reshape(shp)),
                        shard_heads(k.reshape(shp)),
                        shard_heads(v.reshape(shp)),
                        shard_heads(w.astype(x.dtype).reshape(shp)),
                        p["u"], s0, impl=impl)
    o = shard_heads(o)
    # per-head groupnorm
    of = o.astype(jnp.float32)
    mu = of.mean(-1, keepdims=True)
    var = ((of - mu) ** 2).mean(-1, keepdims=True)
    of = (of - mu) * jax.lax.rsqrt(var + 64e-5)
    o = (of * p["ln_scale"].astype(jnp.float32)).astype(x.dtype)

    out = (o.reshape(B, S, D) * jax.nn.silu(g)) @ p["w_o"].astype(x.dtype)
    new_state = None if state is None else {
        "wkv": s_last, "shift_t": new_last.astype(x.dtype)}
    return out, new_state


def rwkv_channel_mix(cfg: ModelConfig, p, x, *, state=None):
    from repro.sharding.specs import DP, constrain
    B, S, D = x.shape
    x = constrain(x, DP, None, None)
    last = state["shift_c"] if state is not None else jnp.zeros(
        (B, D), x.dtype)
    prev, new_last = _token_shift(x, last)
    k = _lerp(x, prev, p["cm_mu_k"]) @ p["cm_w_up"].astype(x.dtype)
    r = jax.nn.sigmoid(_lerp(x, prev, p["cm_mu_r"])
                       @ p["cm_w_r"].astype(x.dtype))
    y = (jax.nn.relu(k) ** 2) @ p["cm_w_down"].astype(x.dtype)
    return r * y, (None if state is None else new_last.astype(x.dtype))
