"""Whisper-style encoder-decoder LM (audio backbone, frontend stubbed).

``input_specs`` supplies ``frame_embeds`` (B, encoder_seq_len, d_model) —
the output the mel+conv frontend would produce (the assignment's one
allowed stub).  The encoder is a non-causal transformer over frames; the
decoder is a causal transformer with per-layer cross-attention to the
encoder output.

Documented simplification: sinusoidal positions for both encoder and
decoder (the released decoder uses a learned 448-position table, which
cannot express the assigned 32k decode shape).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _sinusoid(S, D, dtype):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, D, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (dim / D))
    pe = jnp.zeros((S, D), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (D + 1) // 2]))
    return pe.astype(dtype)


def _init_enc_layer(cfg, key):
    k1, k2 = jax.random.split(key)
    return {"norm1": L.init_norm(cfg), "attn": L.init_attn(cfg, k1),
            "norm2": L.init_norm(cfg), "ffn": L.init_mlp(cfg, k2)}


def _init_dec_layer(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": L.init_norm(cfg), "attn": L.init_attn(cfg, k1),
            "norm_x": L.init_norm(cfg), "xattn": L.init_attn(cfg, k2),
            "norm2": L.init_norm(cfg), "ffn": L.init_mlp(cfg, k3)}


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    ekeys = jax.random.split(ks[0], cfg.num_encoder_layers)
    dkeys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": {"table": (jax.random.normal(
            ks[2], (cfg.vocab_size, cfg.d_model)) * 0.02
        ).astype(cfg.param_dtype)},
        "enc": jax.vmap(lambda k: _init_enc_layer(cfg, k))(ekeys),
        "enc_norm": L.init_norm(cfg),
        "dec": jax.vmap(lambda k: _init_dec_layer(cfg, k))(dkeys),
        "final_norm": L.init_norm(cfg),
    }


def encode(cfg: ModelConfig, params, frame_embeds, *, impl="auto"):
    """frame_embeds: (B, Se, D) from the stubbed conv frontend."""
    x = frame_embeds.astype(cfg.dtype)
    x = x + _sinusoid(x.shape[1], x.shape[2], x.dtype)[None]

    def body(x, lp):
        h, _ = L.attn_apply(cfg, lp["attn"],
                            L.apply_norm(cfg, lp["norm1"], x),
                            mode="train", causal=False, use_rope=False,
                            impl=impl)
        x = x + h
        x = x + L.mlp_apply(cfg, lp["ffn"],
                            L.apply_norm(cfg, lp["norm2"], x))
        return x, None

    from repro.kernels import ops as _ops
    x, _ = jax.lax.scan(body, x, params["enc"],
                        unroll=_ops.CONFIG["unroll"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def init_dec_cache(cfg: ModelConfig, batch, cache_len, enc_out=None,
                   params=None, dtype=None):
    """Self-attention cache + (precomputed) cross K/V for every layer."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    n = cfg.num_layers
    self_c = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape),
        L.init_attn_cache(cfg, batch, cache_len, dtype))
    if enc_out is not None:
        xkv = jax.vmap(lambda lp: L.cross_kv(cfg, lp["xattn"], enc_out))(
            params["dec"])
    else:
        dh = cfg.head_dim_
        z = jnp.zeros((n, batch, cfg.encoder_seq_len, cfg.num_kv_heads, dh),
                      dtype)
        xkv = {"k": z, "v": z}
    return {"self": self_c, "cross": xkv}


def grow_cache(cfg: ModelConfig, cache, extra_tokens: int):
    """Grows the decoder self-attention cache by ``extra_tokens`` slots.
    The cross K/V covers the (fixed) encoder sequence and never grows —
    its length dim must not be confused with the prefill length."""
    leaf = cache["self"]["k"]
    cur = leaf.shape[leaf.ndim + L.ATTN_CACHE_LEN_AXIS]
    return {"self": L.grow_attn_cache(cache["self"], cur + extra_tokens),
            "cross": cache["cross"]}


def decode_forward(cfg: ModelConfig, params, tokens, enc_out=None, *,
                   mode="train", cache=None, pos=None, impl="auto",
                   remat=True):
    """Decoder forward.  Returns (hidden, new_cache, aux=0).

    train/prefill: enc_out required; decode: cache carries cross K/V.
    """
    B, S = tokens.shape
    x = params["embed"]["table"].astype(cfg.dtype)[tokens]
    base = 0 if pos is None else pos
    pe = _sinusoid(32_768 + 8, cfg.d_model, x.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(pe, base, S, axis=0)[None]

    serve = mode in ("prefill", "decode")
    if serve and cache is None:
        cache = init_dec_cache(cfg, B, S, enc_out, params)
    if enc_out is not None and (cache is None or mode == "train"):
        xkv_all = jax.vmap(lambda lp: L.cross_kv(cfg, lp["xattn"], enc_out))(
            params["dec"])
    else:
        xkv_all = cache["cross"]

    def body(x, xs):
        if serve:
            lp, sc, xkv = xs
        else:
            lp, sc, xkv = xs[0], None, xs[1]
        h, nsc = L.attn_apply(cfg, lp["attn"],
                              L.apply_norm(cfg, lp["norm1"], x),
                              mode=mode, cache=sc, pos=pos, use_rope=False,
                              impl=impl)
        x = x + h
        x = x + L.cross_attn_apply(cfg, lp["xattn"],
                                   L.apply_norm(cfg, lp["norm_x"], x),
                                   xkv, impl=impl)
        x = x + L.mlp_apply(cfg, lp["ffn"],
                            L.apply_norm(cfg, lp["norm2"], x))
        return x, nsc

    if remat and mode == "train":
        body = jax.checkpoint(body)
    from repro.kernels import ops as _ops
    xs = (params["dec"], cache["self"], xkv_all) if serve \
        else (params["dec"], xkv_all)
    x, new_self = jax.lax.scan(body, x, xs,
                               unroll=_ops.CONFIG["unroll"])
    new_cache = {"self": new_self, "cross": xkv_all} if serve else None
    return L.apply_norm(cfg, params["final_norm"], x), new_cache, \
        jnp.float32(0.0)
