"""Unified decoder-LM engine for all assigned architectures.

A model is: embed -> [first_k_dense unrolled blocks] -> scan over
``num_periods`` copies of ``cfg.pattern`` (stacked params, single trace)
-> [tail unrolled blocks] -> final norm -> (tied) LM head.

Scan-over-periods keeps the HLO size independent of depth — essential for
compiling 46-layer configs on the CPU dry-run host — and is also the
production choice (XLA pipelines the scanned layer).

The LM head is *chunked*: loss and argmax scan over sequence chunks so the
(B, S, vocab) logits tensor never materializes (vocab reaches 256k).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_LOCAL, RGLRU, RWKV, ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import rwkv as W

HEAD_CHUNK = 512


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_block(cfg: ModelConfig, key, kind: str, use_moe: bool,
                dense_ff: Optional[int] = None):
    ks = jax.random.split(key, 6)
    p = {"norm1": L.init_norm(cfg), "norm2": L.init_norm(cfg)}
    if kind in (ATTN, ATTN_LOCAL):
        p["attn"] = L.init_attn(cfg, ks[0])
    elif kind == RGLRU:
        p["rglru"] = R.init_rglru(cfg, ks[0])
    elif kind == RWKV:
        p["tm"] = W.init_rwkv(cfg, ks[0])
        # rwkv channel-mix params live inside tm dict; norm2 feeds it
        return p
    else:
        raise ValueError(kind)
    if use_moe:
        p["ffn"] = M.init_moe(cfg, ks[1])
    else:
        p["ffn"] = L.init_mlp(cfg, ks[1], d_ff=dense_ff)
    if cfg.post_norm:
        p["post_norm1"] = L.init_norm(cfg)
        p["post_norm2"] = L.init_norm(cfg)
    return p


def _layer_plan(cfg: ModelConfig):
    """(first_k_dense, num_periods, tail_kinds)."""
    fkd = cfg.moe.first_k_dense if cfg.moe else 0
    remaining = cfg.num_layers - fkd
    period = len(cfg.pattern)
    return fkd, remaining // period, cfg.pattern[:remaining % period]


def init_params(cfg: ModelConfig, key):
    fkd, nper, tail = _layer_plan(cfg)
    keys = jax.random.split(key, 4 + fkd + len(tail))
    use_moe = cfg.moe is not None
    p = {"embed": {"table": (jax.random.normal(
        keys[0], (cfg.vocab_size, cfg.d_model)) * 0.02
    ).astype(cfg.param_dtype)}}

    dense_ff = cfg.d_ff * (cfg.moe.dense_ff_mult if use_moe else 1)
    p["head_blocks"] = [
        _init_block(cfg, keys[1 + i], cfg.pattern[0], use_moe=False,
                    dense_ff=dense_ff)
        for i in range(fkd)]

    if nper:
        def one_period(k):
            kk = jax.random.split(k, len(cfg.pattern))
            return {f"b{j}": _init_block(cfg, kk[j], kind, use_moe)
                    for j, kind in enumerate(cfg.pattern)}
        pkeys = jax.random.split(keys[1 + fkd], nper)
        p["periods"] = jax.vmap(one_period)(pkeys)
    p["tail"] = [
        _init_block(cfg, keys[2 + fkd + i], kind, use_moe)
        for i, kind in enumerate(tail)]

    p["final_norm"] = L.init_norm(cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = {"w": (jax.random.normal(
            keys[-1], (cfg.d_model, cfg.vocab_size)) * 0.02
        ).astype(cfg.param_dtype)}
    return p


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------
def _init_block_cache(cfg: ModelConfig, kind, batch, cache_len, dtype):
    if kind == ATTN_LOCAL:
        # ring buffer: a sliding-window layer never needs more than
        # ``window`` live keys (decode writes at pos % window)
        return L.init_attn_cache(cfg, batch, min(cache_len, cfg.window),
                                 dtype)
    if kind == ATTN:
        return L.init_attn_cache(cfg, batch, cache_len, dtype)
    if kind == RGLRU:
        return R.init_rglru_state(cfg, batch, dtype)
    if kind == RWKV:
        return W.init_rwkv_state(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch, cache_len, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    fkd, nper, tail = _layer_plan(cfg)
    c = {"head_blocks": [
        _init_block_cache(cfg, cfg.pattern[0], batch, cache_len, dtype)
        for _ in range(fkd)]}
    if nper:
        def stack(x):
            return jnp.broadcast_to(x[None], (nper,) + x.shape)
        per = {f"b{j}": _init_block_cache(cfg, kind, batch, cache_len, dtype)
               for j, kind in enumerate(cfg.pattern)}
        c["periods"] = jax.tree.map(stack, per)
    c["tail"] = [_init_block_cache(cfg, kind, batch, cache_len, dtype)
                 for kind in tail]
    return c


def grow_cache(cfg: ModelConfig, cache, extra_tokens: int):
    """Grows every self-attention KV cache by ``extra_tokens`` decode
    slots.  Walks the same layer plan as ``init_cache``, so it knows
    which blocks are attention (grow along the tagged length axis),
    which are sliding-window (ring buffers never need more than
    ``window`` slots), and which are recurrent state (RGLRU/RWKV: no
    length axis, returned untouched) — no shape guessing.

    Sliding-window blocks come out of prefill with a LINEAR cache of the
    full prompt length; when the prompt is longer than the window that
    cache is shrunk to a ``window``-slot ring (last ``window`` keys, in
    slot order p % window) so decode writes at pos % window land on the
    oldest live key instead of clamping past the buffer end."""
    def grow_block(kind, c):
        if kind not in (ATTN, ATTN_LOCAL):
            return c
        leaf = c["k"]
        cur = leaf.shape[leaf.ndim + L.ATTN_CACHE_LEN_AXIS]
        if kind == ATTN_LOCAL:
            if cur > cfg.window:
                return L.ring_attn_cache(c, cfg.window, cur)
            return L.grow_attn_cache(c, min(cur + extra_tokens,
                                            cfg.window))
        return L.grow_attn_cache(c, cur + extra_tokens)

    fkd, nper, tail = _layer_plan(cfg)
    out = {"head_blocks": [grow_block(cfg.pattern[0], c)
                           for c in cache["head_blocks"]]}
    if nper:
        out["periods"] = {f"b{j}": grow_block(kind,
                                              cache["periods"][f"b{j}"])
                          for j, kind in enumerate(cfg.pattern)}
    out["tail"] = [grow_block(kind, c)
                   for kind, c in zip(tail, cache["tail"])]
    return out


def insert_cache(cfg: ModelConfig, slot_cache, prefill_cache, slots,
                 plens):
    """Scatters a padded-bucket prefill's per-request KV into rows of a
    persistent slot cache — the admission step of the continuous-
    batching engine (``repro.serving``).

    ``prefill_cache`` is the LINEAR cache a ``mode="prefill"`` forward
    over a (b, Pb) padded token bucket returns (every attention layer
    holds Pb entries, pad positions included).  ``slots`` (b,) int32
    names the destination row per request; an out-of-range slot (the
    bucket's batch-padding rows use ``num_slots``) is dropped by the
    scatter.  ``plens`` (b,) int32 is each request's TRUE prompt length
    (pads excluded) — it only matters for sliding-window layers, where
    the over-long linear cache must become a ring the way
    ``grow_cache`` does, but per request: keep the last ``w`` REAL keys
    (positions [max(plen-w, 0), ...)), rolled so position p sits at
    slot p % w — garbage from pad/garbage positions beyond plen is
    never attended because decode writes positions plen, plen+1, ... in
    order before the causal q_offset mask ever exposes them.

    Global-attention rows are zero-padded to the slot length: the zero
    fill (rather than leaving a stale previous occupant) keeps evicted
    slots inert and makes reused-slot contents deterministic.
    Recurrent blocks (RGLRU/RWKV) have no length axis a padded prefill
    can be corrected along — the serving engine refuses those configs
    up front, so this walk only ever meets attention blocks.
    """
    def place_leaf(window):
        def core(dst, src):
            # dst (S, L, KV, dh) one slot-cache leaf; src (b, Pb, ...)
            b, Pb = src.shape[0], src.shape[1]
            L = dst.shape[1]
            if Pb <= L:      # linear prefix fits: zero-fill the tail
                pads = [(0, 0)] * src.ndim
                pads[1] = (0, L - Pb)
                rows = jnp.pad(src, pads)
            else:            # ring-convert with each request's true len
                assert window > 0, "global cache shorter than a prompt"

                def ring_row(row, plen):
                    start = jnp.clip(plen - L, 0, Pb - L)
                    win = jax.lax.dynamic_slice(
                        row, (start,) + (0,) * (row.ndim - 1),
                        (L,) + row.shape[1:])
                    return jnp.roll(win, start, axis=0)

                rows = jax.vmap(ring_row)(src, plens)
            return dst.at[slots].set(rows.astype(dst.dtype), mode="drop")

        return core

    def place_block(kind, dst, src):
        if kind not in (ATTN, ATTN_LOCAL):
            raise ValueError(
                f"insert_cache: {kind} blocks have no insertable KV")
        fn = place_leaf(cfg.window if kind == ATTN_LOCAL else 0)
        # periods leaves carry a leading stacked axis; vmap over it
        extra = jax.tree.leaves(dst)[0].ndim - 4
        for _ in range(extra):
            fn = jax.vmap(fn)
        return jax.tree.map(fn, dst, src)

    fkd, nper, tail = _layer_plan(cfg)
    out = {"head_blocks": [
        place_block(cfg.pattern[0], d, s)
        for d, s in zip(slot_cache["head_blocks"],
                        prefill_cache["head_blocks"])]}
    if nper:
        out["periods"] = {
            f"b{j}": place_block(kind, slot_cache["periods"][f"b{j}"],
                                 prefill_cache["periods"][f"b{j}"])
            for j, kind in enumerate(cfg.pattern)}
    out["tail"] = [place_block(kind, d, s)
                   for kind, d, s in zip(tail, slot_cache["tail"],
                                         prefill_cache["tail"])]
    return out


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _apply_block(cfg: ModelConfig, kind, bp, x, *, use_moe, mode, cache,
                 pos, impl):
    aux = jnp.float32(0.0)
    if kind == RWKV:
        # (§Perf iter 4b, REVERTED: pinning the stream replicated before
        # the norms cut all-gathers 3x but doubled peak memory — the
        # D-sharded stream is the Pareto choice; see EXPERIMENTS.md)
        n1 = L.apply_norm(cfg, bp["norm1"], x)
        y, st = W.rwkv_time_mix(cfg, bp["tm"], n1, state=cache, impl=impl)
        x = x + y
        n2 = L.apply_norm(cfg, bp["norm2"], x)
        y2, st_c = W.rwkv_channel_mix(cfg, bp["tm"], n2, state=cache)
        x = x + y2
        new_cache = None if cache is None else {
            "wkv": st["wkv"], "shift_t": st["shift_t"], "shift_c": st_c}
        return x, new_cache, aux

    n1 = L.apply_norm(cfg, bp["norm1"], x)
    if kind in (ATTN, ATTN_LOCAL):
        y, new_cache = L.attn_apply(cfg, bp["attn"], n1, kind=kind,
                                    mode=mode, cache=cache, pos=pos,
                                    impl=impl)
    else:  # RGLRU
        y, new_cache = R.rglru_apply(cfg, bp["rglru"], n1, mode=mode,
                                     state=cache, impl=impl)
    if cfg.post_norm:
        y = L.apply_norm(cfg, bp["post_norm1"], y)

    if cfg.parallel_block:
        m = L.mlp_apply(cfg, bp["ffn"], n1)
        return x + y + m, new_cache, aux

    x = x + y
    n2 = L.apply_norm(cfg, bp["norm2"], x)
    if use_moe:
        m, aux = M.moe_apply(cfg, bp["ffn"], n2)
    else:
        m = L.mlp_apply(cfg, bp["ffn"], n2)
    if cfg.post_norm:
        m = L.apply_norm(cfg, bp["post_norm2"], m)
    return x + m, new_cache, aux


# ---------------------------------------------------------------------------
# Forward -> final hidden states
# ---------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, tokens, *, embeds=None, mode="train",
            cache=None, pos=None, impl="auto", remat=True):
    """Returns (hidden (B,S,D), new_cache, aux_loss).

    tokens: (B, St) int32.  embeds: optional (B, Se, D) modality-frontend
    embeddings prepended to the token embeddings (VLM stub carve-out).
    """
    use_moe = cfg.moe is not None
    fkd, nper, tail = _layer_plan(cfg)
    x = params["embed"]["table"].astype(cfg.dtype)[tokens]
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)

    serve = mode in ("prefill", "decode")
    if serve and cache is None:
        assert mode == "prefill", "decode requires an existing cache"
        cache = init_cache(cfg, x.shape[0], x.shape[1])
    new_cache = {"head_blocks": [], "tail": []} if serve else None

    for i in range(fkd):
        c = cache["head_blocks"][i] if serve else None
        x, nc, a = _apply_block(cfg, cfg.pattern[0], params["head_blocks"][i],
                                x, use_moe=False, mode=mode, cache=c,
                                pos=pos, impl=impl)
        if serve:
            new_cache["head_blocks"].append(nc)
    aux = jnp.float32(0.0)

    if nper:
        def body(carry, xs):
            x, aux = carry
            if serve:
                pp, pc = xs
            else:
                pp, pc = xs, {}
            npc = {}
            for j, kind in enumerate(cfg.pattern):
                x, nc, a = _apply_block(
                    cfg, kind, pp[f"b{j}"], x, use_moe=use_moe, mode=mode,
                    cache=pc.get(f"b{j}"), pos=pos, impl=impl)
                npc[f"b{j}"] = nc
                aux = aux + a
            return (x, aux), (npc if serve else None)

        if remat and mode == "train":
            body = jax.checkpoint(body)
        from repro.kernels import ops as _ops
        xs = (params["periods"], cache["periods"]) if serve \
            else params["periods"]
        (x, aux), percache = jax.lax.scan(body, (x, aux), xs,
                                          unroll=_ops.CONFIG["unroll"])
        if serve:
            new_cache["periods"] = percache

    for i, kind in enumerate(tail):
        c = cache["tail"][i] if serve else None
        x, nc, a = _apply_block(cfg, kind, params["tail"][i], x,
                                use_moe=use_moe, mode=mode, cache=c,
                                pos=pos, impl=impl)
        aux = aux + a
        if serve:
            new_cache["tail"].append(nc)

    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# LM head (chunked)
# ---------------------------------------------------------------------------
def _head_w(cfg: ModelConfig, params):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


def _softcap(cfg, logits):
    if cfg.final_softcap > 0:
        return cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def logits_fn(cfg: ModelConfig, params, hidden):
    """Full logits — only for small vocab / decode (B, 1, V) use."""
    w = _head_w(cfg, params).astype(hidden.dtype)
    return _softcap(cfg, (hidden @ w).astype(jnp.float32))


def _chunk_scan(cfg, params, hidden, fn):
    """Scan fn(logits_chunk) over sequence chunks of HEAD_CHUNK."""
    B, S, D = hidden.shape
    cs = min(HEAD_CHUNK, S)
    if S % cs:
        cs = S  # fall back to single chunk for ragged small cases
    n = S // cs
    w = _head_w(cfg, params)

    def body(_, h_chunk):
        logits = _softcap(
            cfg, (h_chunk @ w.astype(h_chunk.dtype)).astype(jnp.float32))
        return None, fn(logits)

    from repro.kernels import ops as _ops
    hs = hidden.reshape(B, n, cs, D).swapaxes(0, 1)
    _, out = jax.lax.scan(jax.checkpoint(body), None, hs,
                          unroll=_ops.CONFIG["unroll"])
    return out, n, cs


def lm_loss(cfg: ModelConfig, params, hidden, labels, mask=None):
    """Mean masked cross-entropy, never materializing (B,S,V)."""
    B, S, _ = hidden.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)

    cs = min(HEAD_CHUNK, S)
    if S % cs:
        cs = S
    n = S // cs
    w = _head_w(cfg, params)
    hs = hidden.reshape(B, n, cs, -1).swapaxes(0, 1)
    ls = labels.reshape(B, n, cs).swapaxes(0, 1)
    ms = mask.reshape(B, n, cs).swapaxes(0, 1)

    def body(carry, xs):
        h, lab, mk = xs
        logits = _softcap(
            cfg, (h @ w.astype(h.dtype)).astype(jnp.float32))
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        lab_logit = jnp.take_along_axis(
            logits, lab[..., None], axis=-1)[..., 0]
        nll = (lse - lab_logit) * mk
        return (carry[0] + nll.sum(), carry[1] + mk.sum()), None

    from repro.kernels import ops as _ops
    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.float32(0.0), jnp.float32(0.0)),
        (hs, ls, ms), unroll=_ops.CONFIG["unroll"])
    return tot / jnp.maximum(cnt, 1.0)


def predict_argmax(cfg: ModelConfig, params, hidden):
    """Greedy per-position prediction (B, S) int32 — the teacher vote."""
    B, S, _ = hidden.shape
    out, n, cs = _chunk_scan(
        cfg, params, hidden,
        lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))
    return out.swapaxes(0, 1).reshape(B, S)
