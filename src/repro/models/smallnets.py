"""Small classifiers for the paper's experiments: MLP (tabular) and the
paper's CNN (two 5x5 convs 6/16 ch + 2x2 pools + FC 120/84), plus a
VGG-9-lite for the CelebA-style task.

Interface: init(key) -> params; apply(params, X) -> logits.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


def _dense(key, nin, nout):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (nin, nout)) * (nin ** -0.5),
            "b": jnp.zeros((nout,))}


def _conv(key, kh, kw, cin, cout):
    k1, _ = jax.random.split(key)
    fan = kh * kw * cin
    return {"w": jax.random.normal(k1, (kh, kw, cin, cout)) * fan ** -0.5,
            "b": jnp.zeros((cout,))}


def _conv2d(p, x, stride=1, padding="VALID"):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


@dataclass(frozen=True)
class MLP:
    """Tabular classifier: features -> hidden -> hidden -> classes."""
    num_features: int
    num_classes: int
    hidden: int = 64

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {"l1": _dense(k1, self.num_features, self.hidden),
                "l2": _dense(k2, self.hidden, self.hidden),
                "l3": _dense(k3, self.hidden, self.num_classes)}

    def apply(self, p, x):
        h = jax.nn.relu(x @ p["l1"]["w"] + p["l1"]["b"])
        h = jax.nn.relu(h @ p["l2"]["w"] + p["l2"]["b"])
        return h @ p["l3"]["w"] + p["l3"]["b"]


@dataclass(frozen=True)
class PaperCNN:
    """The paper's MNIST/SVHN CNN (LeNet-style, §5)."""
    image_size: int = 28
    channels: int = 1
    num_classes: int = 10

    def init(self, key):
        ks = jax.random.split(key, 5)
        s = self.image_size
        s = (s - 4) // 2          # conv5 + pool
        s = (s - 4) // 2          # conv5 + pool
        self_flat = s * s * 16
        return {"c1": _conv(ks[0], 5, 5, self.channels, 6),
                "c2": _conv(ks[1], 5, 5, 6, 16),
                "f1": _dense(ks[2], self_flat, 120),
                "f2": _dense(ks[3], 120, 84),
                "f3": _dense(ks[4], 84, self.num_classes)}

    def apply(self, p, x):
        # x: (B, H, W, C) float32
        h = _pool(jax.nn.relu(_conv2d(p["c1"], x)))
        h = _pool(jax.nn.relu(_conv2d(p["c2"], h)))
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ p["f1"]["w"] + p["f1"]["b"])
        h = jax.nn.relu(h @ p["f2"]["w"] + p["f2"]["b"])
        return h @ p["f3"]["w"] + p["f3"]["b"]


@dataclass(frozen=True)
class VGG9Lite:
    """Thin VGG-9 (appendix Table 12 structure, reduced widths for CPU)."""
    image_size: int = 32
    channels: int = 3
    num_classes: int = 2
    width: int = 16

    def init(self, key):
        w = self.width
        ks = jax.random.split(key, 9)
        s = self.image_size // 8
        return {
            "c1": _conv(ks[0], 3, 3, self.channels, w),
            "c2": _conv(ks[1], 3, 3, w, 2 * w),
            "c3": _conv(ks[2], 3, 3, 2 * w, 4 * w),
            "c4": _conv(ks[3], 3, 3, 4 * w, 4 * w),
            "c5": _conv(ks[4], 3, 3, 4 * w, 8 * w),
            "c6": _conv(ks[5], 3, 3, 8 * w, 8 * w),
            "f1": _dense(ks[6], s * s * 8 * w, 128),
            "f2": _dense(ks[7], 128, 128),
            "f3": _dense(ks[8], 128, self.num_classes),
        }

    def apply(self, p, x):
        h = jax.nn.relu(_conv2d(p["c1"], x, padding="SAME"))
        h = _pool(jax.nn.relu(_conv2d(p["c2"], h, padding="SAME")))
        h = jax.nn.relu(_conv2d(p["c3"], h, padding="SAME"))
        h = _pool(jax.nn.relu(_conv2d(p["c4"], h, padding="SAME")))
        h = jax.nn.relu(_conv2d(p["c5"], h, padding="SAME"))
        h = _pool(jax.nn.relu(_conv2d(p["c6"], h, padding="SAME")))
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ p["f1"]["w"] + p["f1"]["b"])
        h = jax.nn.relu(h @ p["f2"]["w"] + p["f2"]["b"])
        return h @ p["f3"]["w"] + p["f3"]["b"]
