"""Shared transformer layers: norms, RoPE, GQA attention, gated MLPs.

All modules are (init, apply) function pairs over plain dict pytrees —
no framework.  Compute runs in ``cfg.dtype`` with f32 norms/softmax;
parameters are stored in ``cfg.param_dtype``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_LOCAL, ModelConfig
from repro.kernels import ops


def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig):
    p = {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), cfg.param_dtype)
    return p


def apply_norm(cfg: ModelConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (supports stablelm-style partial rotary)
# ---------------------------------------------------------------------------
def apply_rope(x, positions, theta: float, pct: float = 1.0):
    """x: (B, S, N, dh); positions: (S,) shared across the batch, or
    (B, S) per-row absolute positions (continuous-batching decode, where
    every cache slot sits at its own position)."""
    B, S, N, dh = x.shape
    rot = int(dh * pct)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 2:                      # (B, S) per-row
        ang = positions[..., None].astype(jnp.float32) * freqs
        cos = jnp.cos(ang)[:, :, None, :]        # (B, S, 1, half)
        sin = jnp.sin(ang)[:, :, None, :]
    else:
        ang = positions.reshape(-1, 1).astype(jnp.float32) * freqs
        cos = jnp.cos(ang)[None, :, None, :]     # (1, S, 1, half)
        sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = xr[..., :half], xr[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Attention block (GQA / MQA / local / softcap / cross)
# ---------------------------------------------------------------------------
def init_attn(cfg: ModelConfig, key, cross=False):
    dh = cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = cfg.param_dtype
    kv_in = cfg.d_model
    return {
        "wq": dense_init(k1, (cfg.d_model, cfg.num_heads * dh), dt),
        "wk": dense_init(k2, (kv_in, cfg.num_kv_heads * dh), dt),
        "wv": dense_init(k3, (kv_in, cfg.num_kv_heads * dh), dt),
        "wo": dense_init(k4, (cfg.num_heads * dh, cfg.d_model), dt),
    }


# KV caches are built here and only here.  The cache-length dim is
# tagged by its position from the END so growth code never guesses it
# from sizes (stacked caches add leading dims: (layers, B, L, KV, dh)).
ATTN_CACHE_LEN_AXIS = -3


def init_attn_cache(cfg: ModelConfig, batch, cache_len, dtype):
    dh = cfg.head_dim_
    return {
        "k": jnp.zeros((batch, cache_len, cfg.num_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads, dh), dtype),
    }


def grow_attn_cache(cache, target_len):
    """Pads one {"k","v"} cache to ``target_len`` along the tagged
    length axis (no-op if already that long)."""
    def pad(leaf):
        axis = leaf.ndim + ATTN_CACHE_LEN_AXIS
        cur = leaf.shape[axis]
        if cur >= target_len:
            return leaf
        pads = [(0, 0)] * leaf.ndim
        pads[axis] = (0, target_len - cur)
        return jnp.pad(leaf, pads)
    return jax.tree.map(pad, cache)


def ring_attn_cache(cache, window, cur):
    """Converts a linear prefill cache holding positions [0, cur) with
    ``cur > window`` into a ``window``-slot ring: keeps the last
    ``window`` keys, rolled so the key for position p sits at slot
    p % window — the slot the next decode write (at pos % window)
    overwrites is then exactly the oldest live position."""
    shift = cur % window

    def conv(leaf):
        axis = leaf.ndim + ATTN_CACHE_LEN_AXIS
        idx = [slice(None)] * leaf.ndim
        idx[axis] = slice(cur - window, cur)
        return jnp.roll(leaf[tuple(idx)], shift, axis=axis)
    return jax.tree.map(conv, cache)


def attn_apply(cfg: ModelConfig, p, x, *, kind=ATTN, mode="train",
               cache=None, pos=None, impl="auto", causal=True,
               use_rope=True):
    """Self-attention.  Returns (y, new_cache).

    mode: "train" (no cache) | "prefill" (returns populated cache) |
    "decode" (x is (B,1,D); cache holds cache_len entries; pos is the
    absolute position of the new token — a scalar shared by the batch,
    or a (B,) int32 vector of PER-ROW positions for continuous-batching
    decode, where every cache slot advances independently).
    """
    from repro.sharding.specs import shard_heads
    B, S, D = x.shape
    dh = cfg.head_dim_
    window = cfg.window if kind == ATTN_LOCAL else 0
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.num_heads, dh)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, cfg.num_kv_heads, dh)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, cfg.num_kv_heads, dh)
    q, k, v = shard_heads(q), shard_heads(k), shard_heads(v)

    if mode in ("train", "prefill"):
        if use_rope:
            positions = jnp.arange(S)
            q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_pct)
        o = ops.attention(q, k, v, causal=causal, window=window,
                          softcap=cfg.attn_softcap, impl=impl)
        new_cache = {"k": k, "v": v} if mode == "prefill" else None
    else:  # decode
        pos = jnp.asarray(pos)
        per_row = pos.ndim == 1                  # (B,) slot positions
        if use_rope:
            positions = pos[:, None] if per_row else jnp.full((1,), pos)
            q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_pct)
        Lc = cache["k"].shape[1]
        ring = window > 0 and Lc <= window
        slot = jnp.mod(pos, Lc) if ring else pos
        if per_row:
            def write(c, u, s):
                return jax.lax.dynamic_update_slice(
                    c, u.astype(c.dtype), (s, 0, 0))
            ck = jax.vmap(write)(cache["k"], k, slot)
            cv = jax.vmap(write)(cache["v"], v, slot)
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        # Ring mode (window-bounded cache): every live slot is inside the
        # window by construction — slots fill in order 0..Lc-1 before
        # wrapping — so the causal mask with q_offset=pos stays exact for
        # pos < Lc and all slots are valid afterwards.  No window mask
        # (it would wrongly mask wrapped slots); keys keep their absolute
        # RoPE phases.
        o = ops.attention(q, ck.astype(x.dtype), cv.astype(x.dtype),
                          causal=causal, window=0 if ring else window,
                          softcap=cfg.attn_softcap, q_offset=pos, impl=impl)
        new_cache = {"k": ck, "v": cv}

    o = shard_heads(o)
    y = o.reshape(B, S, cfg.num_heads * dh) @ p["wo"].astype(x.dtype)
    return y, new_cache


def cross_attn_apply(cfg: ModelConfig, p, x, kv_cache, *, impl="auto"):
    """Encoder-decoder cross attention (whisper).  kv_cache: {"k","v"}
    precomputed from encoder output; non-causal, no rope."""
    B, S, D = x.shape
    dh = cfg.head_dim_
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.num_heads, dh)
    o = ops.attention(q, kv_cache["k"].astype(x.dtype),
                      kv_cache["v"].astype(x.dtype),
                      causal=False, impl=impl)
    return o.reshape(B, S, cfg.num_heads * dh) @ p["wo"].astype(x.dtype)


def cross_kv(cfg: ModelConfig, p, enc_out):
    """Precompute cross-attention K/V from encoder output."""
    B, S, _ = enc_out.shape
    dh = cfg.head_dim_
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(
        B, S, cfg.num_kv_heads, dh)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(
        B, S, cfg.num_kv_heads, dh)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.param_dtype
    p = {
        "w_up": dense_init(k1, (cfg.d_model, d_ff), dt),
        "w_down": dense_init(k2, (d_ff, cfg.d_model), dt),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(k3, (cfg.d_model, d_ff), dt)
    return p


def mlp_apply(cfg: ModelConfig, p, x):
    up = x @ p["w_up"].astype(x.dtype)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * up
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype)) * up
    elif cfg.mlp == "gelu":
        h = jax.nn.gelu(up)
    elif cfg.mlp == "relu2":
        h = jax.nn.relu(up) ** 2
    else:
        raise ValueError(cfg.mlp)
    return h @ p["w_down"].astype(x.dtype)
