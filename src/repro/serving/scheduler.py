"""Continuous-batching scheduler: padded buckets, slots, admission.

Pure-python bookkeeping — no jax.  The ``Engine`` (engine.py) owns the
compiled step functions; everything that decides WHICH requests run
WHERE lives here so the scheduling semantics can be property-tested
without touching a model:

  - pow2 ``(batch, prompt_len)`` buckets: prompts are right-padded to
    the next power of two (floor ``min_bucket``) and admission batches
    are padded to a power of two, so the engine's jitted prefill only
    ever sees shapes from a small closed set and never recompiles
    mid-stream.
  - slot allocation: the decode cache has ``num_slots`` rows; a request
    holds exactly one slot from admission to eviction (EOS or token
    budget), and eviction frees exactly that slot.
  - overflow safety: a slot's position counter may never reach
    ``cache_len`` (global KV rows are linearly addressed), so the
    per-request token budget is clamped to ``cache_len - plen`` at
    submit time.

FIFO-with-bucket-match admission: the oldest waiting request fixes the
prompt-length bucket; every waiting request that rounds to the same
bucket joins (up to the free-slot count and ``max_batch``), later
requests in other buckets wait their turn.  Deterministic by
construction — the parity suite replays arrival orders against it.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


def round_pow2(n: int, lo: int = 1) -> int:
    """Smallest power of two >= max(n, lo)."""
    if n < 1 or lo < 1:
        raise ValueError(f"round_pow2 needs positive sizes, got {n}/{lo}")
    p = lo
    while p < n:
        p *= 2
    return p


@dataclass
class RequestState:
    """One generation request, from submit to eviction.

    ``tokens`` accumulates generated ids (the first comes from prefill,
    the rest from decode steps); timing fields are wall-clock seconds
    from the engine's injected clock.  ``pos`` of generated token k is
    ``plen + k`` — decode step k writes KV at ``plen + k - 1``.
    """
    rid: int
    prompt: np.ndarray                      # (plen,) int32
    max_tokens: int                         # clamped token budget
    status: str = "waiting"                 # waiting | running | done
    slot: Optional[int] = None
    tokens: List[int] = field(default_factory=list)
    finish_reason: Optional[str] = None     # "eos" | "length"
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    token_times: List[float] = field(default_factory=list)

    @property
    def plen(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def next_pos(self) -> int:
        """Cache position the next decode step writes: the last emitted
        token's absolute position."""
        return self.plen + len(self.tokens) - 1


@dataclass(frozen=True)
class Admission:
    """One prefill dispatch: ``reqs`` at rows 0..len(reqs)-1 of a
    (batch, bucket_len) padded bucket; rows past len(reqs) are padding
    and target the out-of-range slot id (dropped by the scatter)."""
    reqs: List[RequestState]
    bucket_len: int
    batch: int                              # pow2 >= len(reqs)


class SlotAllocator:
    """Lowest-free-first slot ids — deterministic across runs."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self._free = list(range(num_slots))

    @property
    def free(self) -> List[int]:
        return sorted(self._free)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("no free slots")
        self._free.sort()
        return self._free.pop(0)

    def release(self, slot: int):
        if not 0 <= slot < self.num_slots:
            raise ValueError(f"slot {slot} out of range")
        if slot in self._free:
            raise ValueError(f"slot {slot} already free (double release)")
        self._free.append(slot)


class Scheduler:
    """Waiting queue + slot bookkeeping for the serving engine."""

    def __init__(self, *, num_slots: int, cache_len: int,
                 max_batch: Optional[int] = None, min_bucket: int = 8):
        if num_slots < 1 or cache_len < min_bucket:
            raise ValueError("need >=1 slot and cache_len >= min_bucket")
        max_batch = max_batch or round_pow2(num_slots)
        if max_batch & (max_batch - 1):
            raise ValueError(f"max_batch must be pow2, got {max_batch}")
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        # prompts must leave room for at least one generated token
        self.max_prompt = cache_len - 1
        self.slots = SlotAllocator(num_slots)
        self.waiting: List[RequestState] = []
        self.running: List[RequestState] = []
        self._rid = itertools.count()

    # -- submit ----------------------------------------------------------
    def submit(self, prompt, max_tokens: int, now: float = 0.0,
               rid: Optional[int] = None) -> RequestState:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not 1 <= prompt.shape[0] <= self.max_prompt:
            raise ValueError(
                f"prompt length {prompt.shape[0]} not in "
                f"[1, {self.max_prompt}] (cache_len {self.cache_len})")
        if max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        # overflow clamp: positions stay strictly below cache_len
        budget = min(max_tokens, self.cache_len - prompt.shape[0])
        req = RequestState(
            rid=next(self._rid) if rid is None else rid, prompt=prompt,
            max_tokens=budget, t_submit=now)
        self.waiting.append(req)
        return req

    # -- admission -------------------------------------------------------
    def bucket_of(self, plen: int) -> int:
        """pow2 rounding, capped at cache_len (the bucket must fit the
        slot rows; the cap only binds for non-pow2 cache lengths)."""
        return min(round_pow2(plen, self.min_bucket), self.cache_len)

    def next_admission(self) -> Optional[Admission]:
        """FIFO head fixes the bucket; same-bucket followers join."""
        free = len(self.slots.free)
        if not self.waiting or free == 0:
            return None
        bucket = self.bucket_of(self.waiting[0].plen)
        take = min(free, self.max_batch)
        reqs = [r for r in self.waiting
                if self.bucket_of(r.plen) == bucket][:take]
        for r in reqs:
            self.waiting.remove(r)
            r.slot = self.slots.acquire()
            r.status = "running"
            self.running.append(r)
        return Admission(reqs=reqs, bucket_len=bucket,
                         batch=round_pow2(len(reqs)))

    # -- eviction --------------------------------------------------------
    def evict(self, req: RequestState, reason: str):
        if req.status != "running":
            raise ValueError(f"evicting non-running request {req.rid}")
        req.status = "done"
        req.finish_reason = reason
        self.running.remove(req)
        self.slots.release(req.slot)

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.running
