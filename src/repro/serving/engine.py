"""Continuous-batching inference engine over the ring KV-cache.

The one-shot FedKT artifact is a distilled student each silo then
serves to real traffic; this engine is that serving hot path.  It keeps
ONE persistent ``num_slots``-row KV cache (built by ``Model.init_cache``
— global layers linear at ``cache_len``, sliding-window layers as
``window``-slot rings, exactly the PR-3 ``grow_cache`` layout) and runs
two jitted steps against it:

  prefill  — new requests, right-padded into a pow2 ``(batch,
             prompt_len)`` bucket, prefill in one dispatch; each
             request's KV rows are scattered into its assigned slot
             (``Model.insert_cache``: zero-padded global rows,
             per-true-length ring conversion for window layers) and its
             first token is read at position ``plen - 1``.
  decode   — every step advances ALL slots at once with a (num_slots,)
             per-slot position vector; finished or empty slots decode
             garbage into their own row, which the next admission's
             insert overwrites.  EOS / token-budget eviction frees the
             slot for the next waiting request.

Because both steps only ever see shapes from the closed bucket set —
``(pow2 batch, pow2 prompt_len)`` prefills and the single
``(num_slots, 1)`` decode — jit never recompiles after warmup
(test-enforced via trace-cache counts in tests/test_serving.py).

Scheduling (FIFO bucket admission, slot allocation, overflow clamps)
lives in ``scheduler.py``; per-request bit-identity to the serial
``serve_batch`` reference is pinned by the parity suite.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ATTN, ATTN_LOCAL
from repro.serving.scheduler import RequestState, Scheduler


@dataclass(frozen=True)
class StreamResult:
    """Terminal view of one request: its generated stream + accounting.

    timing keys (seconds): ``ttft`` submit -> first token, ``queue``
    submit -> admission, ``total`` submit -> done; ``token_latencies``
    are per-token gaps (first token measured from admission), the
    per-token latency distribution the bench's p50/p95 summarizes.
    """
    rid: int
    prompt_len: int
    tokens: List[int]
    finish_reason: str
    timing: Dict[str, Any]

    @property
    def num_tokens(self) -> int:
        return len(self.tokens)


def _result(req: RequestState) -> StreamResult:
    times = [req.t_admit] + req.token_times
    lat = [b - a for a, b in zip(times, times[1:])]
    return StreamResult(
        rid=req.rid, prompt_len=req.plen, tokens=list(req.tokens),
        finish_reason=req.finish_reason,
        timing={"ttft": req.t_first - req.t_submit,
                "queue": req.t_admit - req.t_submit,
                "total": req.t_done - req.t_submit,
                "token_latencies": lat})


class Engine:
    """Continuous-batching greedy-decode engine for one decoder model.

    Supported configs: decoder-only, attention blocks only (global
    and/or sliding-window).  Recurrent blocks (RGLRU/RWKV) carry their
    whole past in one state a padded prefill would pollute, and
    encoder-decoder/frontend models need per-request side inputs —
    both are refused up front (``serve_batch`` still serves them in
    fixed batches).  MoE configs run, but capacity dropping couples
    rows of a batch, so per-request bit-identity to the serial
    reference only holds when no token is dropped.
    """

    def __init__(self, model, params, *, num_slots: int = 8,
                 cache_len: int = 256, max_batch: Optional[int] = None,
                 eos_id: Optional[int] = None, min_bucket: int = 8,
                 clock=time.perf_counter):
        cfg = model.cfg
        if cfg.is_encoder_decoder or cfg.frontend_embeds:
            raise NotImplementedError(
                "Engine serves decoder-only token models; use "
                "serve_batch for encoder-decoder/frontend configs")
        bad = [k for k in cfg.pattern if k not in (ATTN, ATTN_LOCAL)]
        if bad:
            raise NotImplementedError(
                f"recurrent blocks {bad} cannot join padded-bucket "
                "prefill (state has no length axis to correct); use "
                "serve_batch")
        if any(k == ATTN_LOCAL for k in cfg.pattern) \
                and cache_len < cfg.window:
            raise ValueError(
                f"cache_len {cache_len} < window {cfg.window}: the ring "
                "would slide earlier than serve_batch's")

        self.model = model
        self.params = params
        self.eos_id = eos_id
        self.clock = clock
        self.scheduler = Scheduler(num_slots=num_slots,
                                   cache_len=cache_len,
                                   max_batch=max_batch,
                                   min_bucket=min_bucket)

        from repro.core.distill import (make_bucket_prefill_step,
                                        make_decode_step)
        self._prefill = jax.jit(make_bucket_prefill_step(model))
        self._decode = jax.jit(make_decode_step(model))
        self._insert = jax.jit(model.insert_cache)
        self._cache = model.init_cache(num_slots, cache_len)
        # host mirrors of the per-slot decode inputs
        self._slot_tok = np.zeros((num_slots,), np.int32)
        self._slot_pos = np.zeros((num_slots,), np.int32)
        self._steps = 0

    # -- introspection ---------------------------------------------------
    @property
    def num_slots(self) -> int:
        return self.scheduler.num_slots

    @property
    def cache_len(self) -> int:
        return self.scheduler.cache_len

    def compile_counts(self) -> Dict[str, int]:
        """Trace-cache sizes of the three jitted steps — the
        zero-recompiles-after-warmup test reads these."""
        return {"prefill": self._prefill._cache_size(),
                "decode": self._decode._cache_size(),
                "insert": self._insert._cache_size()}

    def warmup(self, buckets: Sequence[int] = ()) -> Dict[str, int]:
        """Compiles the decode step and one prefill per (pow2-rounded)
        prompt-length bucket x pow2 batch size up to max_batch, so live
        traffic never hits a compile.  Returns compile_counts()."""
        sched = self.scheduler
        lens = sorted({sched.bucket_of(b) for b in buckets})
        batches = []
        b = 1
        while b <= sched.max_batch:
            batches.append(b)
            b *= 2
        for blen in lens:
            for bb in batches:
                toks = np.zeros((bb, blen), np.int32)
                plens = np.ones((bb,), np.int32)
                slots = np.full((bb,), self.num_slots, np.int32)  # drop
                tok, pc = self._prefill(self.params, toks, plens)
                self._cache = self._insert(self._cache, pc, slots, plens)
                jax.block_until_ready(tok)
        if lens:  # decode compiles once; any warm cache state will do
            out, cache = self._decode(
                self.params, self._slot_tok[:, None], self._cache,
                self._slot_pos)
            self._cache = cache
            jax.block_until_ready(out)
        return self.compile_counts()

    # -- request API -----------------------------------------------------
    def submit(self, prompt, max_tokens: int = 64) -> RequestState:
        return self.scheduler.submit(prompt, max_tokens,
                                     now=self.clock())

    def step(self) -> List[StreamResult]:
        """One scheduler iteration: admit (at most one bucket) + one
        decode sweep over the slots.  Returns requests finished now."""
        done: List[RequestState] = []
        self._admit(done)
        self._decode_sweep(done)
        self._steps += 1
        return [_result(r) for r in done]

    def run(self, max_steps: Optional[int] = None) -> List[StreamResult]:
        """Steps until every submitted request finished; results in
        submit (rid) order."""
        out: List[StreamResult] = []
        while not self.scheduler.idle:
            out.extend(self.step())
            if max_steps is not None and self._steps >= max_steps:
                raise RuntimeError(f"not idle after {max_steps} steps")
        return sorted(out, key=lambda r: r.rid)

    def serve(self, prompts, max_tokens: int = 64) -> List[StreamResult]:
        """Convenience closed loop: submit all, run to completion."""
        for p in prompts:
            self.submit(p, max_tokens)
        return self.run()

    # -- internals -------------------------------------------------------
    def _admit(self, done: List[RequestState]):
        adm = self.scheduler.next_admission()
        if adm is None:
            return
        b, blen = adm.batch, adm.bucket_len
        toks = np.zeros((b, blen), np.int32)
        plens = np.ones((b,), np.int32)
        # padding rows target the out-of-range slot id -> scatter drops
        slots = np.full((b,), self.num_slots, np.int32)
        for i, r in enumerate(adm.reqs):
            toks[i, :r.plen] = r.prompt
            plens[i] = r.plen
            slots[i] = r.slot
        first, pcache = self._prefill(self.params, toks, plens)
        self._cache = self._insert(self._cache, pcache, slots, plens)
        first = np.asarray(first)
        now = self.clock()
        for i, r in enumerate(adm.reqs):
            r.t_admit = now
            self._emit(r, int(first[i]), now, done)

    def _decode_sweep(self, done: List[RequestState]):
        live = self.scheduler.running
        if not live:
            return
        for r in live:
            self._slot_tok[r.slot] = r.tokens[-1]
            self._slot_pos[r.slot] = r.next_pos
        nxt, self._cache = self._decode(
            self.params, self._slot_tok[:, None], self._cache,
            self._slot_pos)
        nxt = np.asarray(nxt)[:, 0]
        now = self.clock()
        for r in list(live):
            self._emit(r, int(nxt[r.slot]), now, done)

    def _emit(self, req: RequestState, token: int, now: float,
              done: List[RequestState]):
        req.tokens.append(token)
        req.token_times.append(now)
        if req.t_first is None:
            req.t_first = now
        finished = (self.eos_id is not None and token == self.eos_id)
        reason = "eos" if finished else "length"
        if finished or len(req.tokens) >= req.max_tokens:
            self.scheduler.evict(req, reason)
            req.t_done = now
            done.append(req)
