"""Serving tier: continuous batching for the distilled student.

- ``Engine`` (engine.py): padded-bucket prefill/decode split over one
  persistent slot cache; ``RequestState``/``StreamResult`` request API.
- ``Scheduler`` (scheduler.py): pure-python buckets / slots / admission.
- ``serve_batch`` (batch.py): fixed-batch serial reference + fallback.
"""
from repro.serving.batch import effective_tokens, serve_batch
from repro.serving.engine import Engine, StreamResult
from repro.serving.scheduler import (Admission, RequestState, Scheduler,
                                     SlotAllocator, round_pow2)

__all__ = ["Engine", "StreamResult", "Scheduler", "SlotAllocator",
           "Admission", "RequestState", "serve_batch",
           "effective_tokens", "round_pow2"]
