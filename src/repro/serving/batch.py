"""Fixed-batch serial serving: the engine's parity reference.

``serve_batch`` prefills a (B, P) prompt batch and greedy-decodes
``gen`` steps with every row at the same position — the original
launch/serve.py demo loop, kept as the bit-identity oracle the
continuous-batching engine is tested against, and as the fallback for
model families the engine refuses (recurrent state, encoder-decoder).

Two fixes over the old demo (ISSUE satellite): generated-token
accounting masks everything after a row's first EOS, and timing comes
back in a stats dict (the bench and the tests consume the same numbers
instead of parsing stdout).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distill import make_decode_step, make_prefill_step


def effective_tokens(tokens: np.ndarray,
                     eos_id: Optional[int]) -> np.ndarray:
    """Per-row count of generated tokens up to and INCLUDING the first
    EOS (everything after it is decode-loop exhaust, not output)."""
    B, G = tokens.shape
    if eos_id is None:
        return np.full((B,), G, np.int64)
    hit = tokens == eos_id
    first = np.where(hit.any(1), hit.argmax(1), G - 1)
    return first + 1


def serve_batch(model, params, prompts: np.ndarray, gen: int,
                cache_len: int = 0, extra=None, eos_id: Optional[int] = None,
                verbose: bool = True):
    """prompts: (B, P) int32.  Returns ((B, gen) generated tokens,
    stats dict).

    stats: prefill_s / decode_s wall times, generated (EOS-masked token
    count across the batch), tok_per_s (generated / decode_s), and the
    per-row effective lengths.  The decode loop itself always runs
    ``gen`` fixed steps — that is what makes this the serial reference
    the continuous-batching engine is bit-compared against; EOS only
    masks the THROUGHPUT accounting (the old print counted dead
    post-EOS tokens as work).
    """
    B, P = prompts.shape

    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))

    batch = {"tokens": jnp.asarray(prompts)}
    if extra:
        batch.update(extra)
    t0 = time.time()
    logits, cache = prefill(params, batch)
    # grow the self-attention caches: room for the gen decode steps (or
    # a caller-requested total cache_len).  Model.grow_cache knows which
    # leaves carry the tagged cache-length dim, so dims that merely
    # equal the prefill length (batch, conv state, cross K/V) are safe.
    cache = model.grow_cache(cache, max(gen, cache_len - P))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out = []
    t0 = time.time()
    for i in range(gen):
        out.append(tok)
        tok, cache = decode(params, tok, cache, jnp.int32(P + i))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    tokens = np.concatenate([np.asarray(t) for t in out], axis=1)
    eff = effective_tokens(tokens, eos_id)
    generated = int(eff.sum())
    stats = {
        "batch": B, "prompt_len": P, "gen": gen,
        "prefill_s": t_prefill, "decode_s": t_decode,
        "generated": generated,
        "tok_per_s": generated / max(t_decode, 1e-9),
        "effective_lens": eff.tolist(),
    }
    if verbose:
        print(f"prefill {B}x{P}: {t_prefill:.2f}s; "
              f"decode {gen} steps: {t_decode:.2f}s "
              f"({stats['tok_per_s']:.1f} tok/s over {generated} "
              "EOS-masked tokens)")
    return tokens, stats
