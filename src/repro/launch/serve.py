"""Serving driver: batched prefill + greedy decode for a trained model.

CPU-scale by default (smoke configs); the same step functions are what
the dry-run lowers against the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
      --smoke --prompt-len 32 --gen 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.core.distill import make_decode_step, make_prefill_step
from repro.models import Model
from repro import checkpoint as ckpt_lib


def serve_batch(model: Model, params, prompts: np.ndarray, gen: int,
                cache_len: int = 0, extra=None, verbose=True):
    """prompts: (B, P) int32.  Returns (B, gen) generated tokens."""
    B, P = prompts.shape

    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))

    batch = {"tokens": jnp.asarray(prompts)}
    if extra:
        batch.update(extra)
    t0 = time.time()
    logits, cache = prefill(params, batch)
    # grow the self-attention caches: room for the gen decode steps (or
    # a caller-requested total cache_len).  Model.grow_cache knows which
    # leaves carry the tagged cache-length dim, so dims that merely
    # equal the prefill length (batch, conv state, cross K/V) are safe.
    cache = model.grow_cache(cache, max(gen, cache_len - P))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    out = []
    t0 = time.time()
    for i in range(gen):
        out.append(tok)
        tok, cache = decode(params, tok, cache, jnp.int32(P + i))
    t_decode = time.time() - t0
    if verbose:
        print(f"prefill {B}x{P}: {t_prefill:.2f}s; "
              f"decode {gen} steps: {t_decode:.2f}s "
              f"({B*gen/max(t_decode,1e-9):.1f} tok/s)")
    return np.concatenate([np.asarray(t) for t in out], axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="phi4-mini-3.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    if args.checkpoint:
        params = ckpt_lib.restore(args.checkpoint, params)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extra = {}
    if cfg.is_encoder_decoder:
        extra["frames"] = jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.encoder_seq_len, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    gen = serve_batch(model, params, prompts, args.gen, extra=extra)
    print("generated:", gen[:, :8], "...")


if __name__ == "__main__":
    main()
