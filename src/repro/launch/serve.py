"""Serving CLI: thin front-end over the continuous-batching engine.

The engine itself lives in ``repro.serving`` (scheduler, slot cache,
prefill/decode split); this module only parses flags, applies the
deployment environment hygiene, builds the model, and drives traffic.

Closed loop (submit everything, drain):

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
      --smoke --concurrent 8 --max-tokens 32

Open loop (seeded Poisson arrivals at --arrival req/s, the pattern
serve_bench measures):

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b \
      --smoke --concurrent 16 --arrival 4 --slots 4

Deployment hygiene (SNIPPETS.md): allocator and logging knobs must be
in the environment BEFORE jax/XLA initialise, so this module imports
NOTHING heavy at module scope — ``main`` sets the env from flags and
only then imports the stack.

  --host-devices N   sets XLA_FLAGS=--xla_force_host_platform_device_count
                     (multi-device CPU topology for mesh dry-runs)
  TF_CPP_MIN_LOG_LEVEL defaults to 2 (mute absl chatter)
  TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD defaults to 2**38 (mute large-
                     alloc warnings for weight-sized host buffers)
  LD_PRELOAD=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 cannot be set
                     from inside a running process — export it in the
                     service unit; host weight staging is measurably
                     faster under tcmalloc.

Models the engine refuses (recurrent state, encoder-decoder) fall back
to the fixed-batch serial path automatically.
"""
from __future__ import annotations

import argparse
import os
import time


def __getattr__(name):
    # back-compat: launch.serve.serve_batch moved to repro.serving
    if name in ("serve_batch", "effective_tokens"):
        from repro import serving
        return getattr(serving, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _apply_env(args):
    os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")
    os.environ.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                          str(2 ** 38))
    if args.host_devices:
        flag = ("--xla_force_host_platform_device_count="
                f"{args.host_devices}")
        os.environ["XLA_FLAGS"] = \
            (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()


def _percentile(xs, q):
    return sorted(xs)[min(len(xs) - 1, int(q * len(xs)))] if xs else 0.0


def _drive(eng, prompts, max_tokens, arrival, seed):
    """Submit ``prompts`` and run to drain.  arrival <= 0: closed loop
    (all at once).  arrival > 0: open loop — seeded exponential
    inter-arrival gaps at ``arrival`` req/s, submitted as engine steps
    pass their deadline."""
    import numpy as np
    if arrival <= 0:
        for p in prompts:
            eng.submit(p, max_tokens)
        return eng.run()
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / arrival, len(prompts))
    t0 = eng.clock()
    deadlines = list(zip(t0 + np.cumsum(gaps), prompts))
    results = []
    while deadlines or not eng.scheduler.idle:
        now = eng.clock()
        while deadlines and deadlines[0][0] <= now:
            _, p = deadlines.pop(0)
            eng.submit(p, max_tokens)
        if eng.scheduler.idle and deadlines:
            time.sleep(min(max(deadlines[0][0] - now, 0.0), 0.01))
            continue                      # idle-wait for next arrival
        results.extend(eng.step())
    return sorted(results, key=lambda r: r.rid)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="max prompt length; lengths are drawn "
                         "uniformly from [1, this] per request")
    ap.add_argument("--max-tokens", "--gen", type=int, default=16,
                    dest="max_tokens")
    ap.add_argument("--concurrent", type=int, default=4,
                    help="number of request streams to serve")
    ap.add_argument("--arrival", type=float, default=0.0,
                    help="open-loop Poisson arrival rate in req/s "
                         "(0 = closed loop: submit all up front)")
    ap.add_argument("--slots", type=int, default=4,
                    help="engine KV-cache slots (concurrent decodes)")
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--eos", type=int, default=None,
                    help="EOS token id for early stream termination")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--serial", action="store_true",
                    help="force the fixed-batch serve_batch path")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="XLA host-platform device count (see hygiene "
                         "notes in the module docstring)")
    args = ap.parse_args(argv)
    _apply_env(args)                      # BEFORE the jax import below

    import jax
    import numpy as np

    from repro import checkpoint as ckpt_lib
    from repro.configs import ARCH_IDS, get_config, get_smoke
    from repro.models import Model
    from repro.serving import Engine, serve_batch

    if args.arch not in ARCH_IDS:
        ap.error(f"unknown arch {args.arch!r} (choose from {ARCH_IDS})")
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.checkpoint:
        params = ckpt_lib.restore(args.checkpoint, params)

    rng = np.random.default_rng(args.seed)
    lens = rng.integers(1, args.prompt_len + 1, args.concurrent)
    prompts = [rng.integers(0, cfg.vocab_size, (int(n),)).astype(np.int32)
               for n in lens]

    try:
        if args.serial:
            raise NotImplementedError("--serial")
        eng = Engine(model, params, num_slots=args.slots,
                     cache_len=args.cache_len, eos_id=args.eos)
    except NotImplementedError as why:
        # recurrent / encoder-decoder configs: fixed-batch fallback
        print(f"serial fixed-batch path ({why})")
        P = args.prompt_len
        batch = np.stack([np.resize(p, P) for p in prompts])
        extra = {}
        if cfg.is_encoder_decoder:
            import jax.numpy as jnp
            extra["frames"] = jnp.asarray(rng.normal(
                0, 1, (len(prompts), cfg.encoder_seq_len, cfg.d_model)),
                jnp.dtype(cfg.dtype))
        tokens, stats = serve_batch(model, params, batch,
                                    args.max_tokens, extra=extra,
                                    eos_id=args.eos)
        print("generated:", tokens[:, :8], "...")
        return stats

    eng.warmup(buckets=[p.shape[0] for p in prompts])
    t0 = eng.clock()
    results = _drive(eng, prompts, args.max_tokens, args.arrival,
                     args.seed)
    wall = eng.clock() - t0
    toks = sum(r.num_tokens for r in results)
    lats = [t for r in results for t in r.timing["token_latencies"]]
    print(f"{len(results)} streams, {toks} tokens in {wall:.2f}s "
          f"({toks / max(wall, 1e-9):.1f} tok/s aggregate)")
    print(f"per-token latency p50 {_percentile(lats, .5)*1e3:.1f}ms "
          f"p95 {_percentile(lats, .95)*1e3:.1f}ms; "
          f"compile counts {eng.compile_counts()}")
    for r in results[:4]:
        print(f"  req {r.rid} plen {r.prompt_len}: {r.tokens[:8]} ...")
    return results


if __name__ == "__main__":
    main()
