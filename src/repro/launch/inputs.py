"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair.

No allocation happens here — these are the shapes the dry-run lowers
against.  Modality frontends are stubbed per the assignment: llava gets
pre-projected ``embeds`` (anyres patches), whisper gets ``frames`` (conv
frontend output); both consume part of the nominal sequence budget.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import Model


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.dtype)
    batch: Dict[str, Any] = {}
    if cfg.is_encoder_decoder:
        batch["frames"] = sds((B, cfg.encoder_seq_len, cfg.d_model), act)
        batch["tokens"] = sds((B, S), jnp.int32)
        batch["labels"] = sds((B, S), jnp.int32)
    elif cfg.frontend_embeds:
        St = S - cfg.frontend_embeds
        assert St > 0, "sequence shorter than frontend embeds"
        batch["embeds"] = sds((B, cfg.frontend_embeds, cfg.d_model), act)
        batch["tokens"] = sds((B, St), jnp.int32)
        batch["labels"] = sds((B, St), jnp.int32)
    else:
        batch["tokens"] = sds((B, S), jnp.int32)
        batch["labels"] = sds((B, S), jnp.int32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape):
    b = train_batch_specs(cfg, shape)
    b.pop("labels", None)
    return b


def decode_specs(cfg: ModelConfig, shape: InputShape):
    """(token, cache, pos) stand-ins for one decode step with a
    ``seq_len`` cache (window-bounded ring caches for local-attention
    layers; recurrent layers carry O(1) states)."""
    B, S = shape.global_batch, shape.seq_len
    model = Model(cfg)
    cache = jax.eval_shape(
        lambda: model.init_cache(B, S, dtype=jnp.dtype(cfg.dtype)))
    token = sds((B, 1), jnp.int32)
    pos = sds((), jnp.int32)
    return token, cache, pos


def concrete_like(spec_tree, seed=0):
    """Materialize small concrete arrays matching a spec tree (tests)."""

    def f(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.zeros(s.shape, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree.map(f, spec_tree)
