from repro.launch.mesh import (make_local_mesh,  # noqa: F401
                               make_production_mesh)
