import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
against the production mesh, prove it shards and fits, and extract the
roofline terms.  (The two lines above MUST precede any jax import: jax
locks the device count at first init.)

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out benchmarks/results]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import (ARCH_IDS, INPUT_SHAPES, TrainConfig, get_config,
                           long_context_variant)
from repro.core.distill import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.launch import analysis
from repro.launch.inputs import (decode_specs, prefill_batch_specs,
                                 train_batch_specs)
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.sharding import (batch_sharding, cache_sharding,
                            opt_state_sharding, param_shardings, replicated)

SKIPS = {
    # whisper decoder max position is 448 in the real model; a 500k
    # decoder cache is architecturally meaningless (DESIGN.md §5)
    ("whisper-tiny", "long_500k"): "enc-dec decoder has no 500k context",
}

# Measured per-arch gradient-accumulation policy (EXPERIMENTS.md §Perf
# iter 7): microbatching divides activation memory but re-replicates
# batch-spread attention (phi4: 24 heads force batch-over-all-axes
# sharding, which needs the full 256 batch) and replays MoE dispatch
# overheads — so it is enabled only where it fixes an OOM without a
# FLOPs collapse.
# deepseek's 64-expert fine-grained dispatch degrades under ANY pregather
# variant (measured 27x useful-ratio collapse, §Perf iter 7b) — baseline
# FSDP gathers are restored for it; root-causing the GSPMD propagation
# failure around the (P,64,D,F) expert stacks is flagged future work.
PREGATHER_POLICY = {"deepseek-moe-16b": False}

MICROBATCH_POLICY = {
    "rwkv6-7b": 4,            # 295 GB -> 21 GB/dev
    "recurrentgemma-2b": 4,   # 165 GB -> 8 GB/dev
    "gemma2-27b": 4,          # 51 GB -> 22 GB/dev, useful ratio flat
    "llava-next-mistral-7b": 4,  # 42 -> 20 GB, useful ratio up
    "granite-20b": 4,         # 65 -> 41 GB (64-head attn shards fine)
}


def probe_cfg(cfg, n_periods: int):
    """Depth-reduced variant with the same per-period structure:
    fkd dense layers + n_periods full patterns, no tail.  Costs are
    affine in depth, so two probes recover exact per-period deltas
    (XLA's HloCostAnalysis counts a while body once — the probes compile
    with the period scan UNROLLED via ops.configure(unroll=True))."""
    fkd = cfg.moe.first_k_dense if cfg.moe else 0
    kw = {"num_layers": fkd + n_periods * len(cfg.pattern)}
    if cfg.is_encoder_decoder:
        kw["num_encoder_layers"] = n_periods
    return cfg.replace(**kw)


def effective_periods(cfg) -> float:
    fkd = cfg.moe.first_k_dense if cfg.moe else 0
    p = len(cfg.pattern)
    rem = cfg.num_layers - fkd
    return rem // p + (rem % p) / p


def resolve_cfg(arch: str, shape_name: str):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    notes = ""
    if shape_name == "long_500k":
        new = long_context_variant(cfg)
        if new is not cfg:
            notes = "SWA long-context variant (window 4096)"
        cfg = new
    if shape.kind != "train":
        cfg = cfg.replace(param_dtype="bfloat16")  # serving weights
    return cfg, shape, notes


def lower_combo(arch: str, shape_name: str, mesh, *, cfg=None):
    """Builds, lowers, and compiles one (arch, shape, mesh) combo.
    Returns (compiled, num_tokens, cfg, param_count, shape, notes)."""
    if cfg is None:
        cfg, shape, notes = resolve_cfg(arch, shape_name)
    else:
        shape = INPUT_SHAPES[shape_name]
        notes = ""
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    pshapes = jax.eval_shape(lambda: model.init(key))
    pshard = param_shardings(pshapes, mesh)

    if shape.kind == "train":
        mb = int(os.environ.get(
            "REPRO_MICROBATCHES",
            str(MICROBATCH_POLICY.get(arch, 1))))
        tcfg = TrainConfig(batch_size=shape.global_batch,
                           seq_len=shape.seq_len, steps=1000,
                           microbatches=mb,
                           pregather=PREGATHER_POLICY.get(arch, True))
        step_fn, opt = make_train_step(model, tcfg)
        oshapes = jax.eval_shape(opt.init, pshapes)
        oshard = opt_state_sharding(oshapes, pshard, mesh)
        bspecs = train_batch_specs(cfg, shape)
        bshard = batch_sharding(bspecs, mesh)

        def step(params, opt_state, batch):
            return step_fn(params, opt_state, batch)

        jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard))
        lowered = jitted.lower(pshapes, oshapes, bspecs)
        num_tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        step_fn = make_prefill_step(model)
        bspecs = prefill_batch_specs(cfg, shape)
        bshard = batch_sharding(bspecs, mesh)
        jitted = jax.jit(lambda p, b: step_fn(p, b),
                         in_shardings=(pshard, bshard))
        lowered = jitted.lower(pshapes, bspecs)
        num_tokens = shape.global_batch * shape.seq_len
    else:  # decode
        step_fn = make_decode_step(model)
        token, cache, pos = decode_specs(cfg, shape)
        tshard = batch_sharding({"t": token}, mesh)["t"]
        cshard = cache_sharding(cache, mesh, shape.global_batch)
        jitted = jax.jit(
            lambda p, t, c, q: step_fn(p, t, c, q),
            in_shardings=(pshard, tshard, cshard, replicated(mesh)))
        lowered = jitted.lower(pshapes, token, cache, pos)
        num_tokens = shape.global_batch

    compiled = lowered.compile()
    pcount = analysis.count_params(pshapes)
    return compiled, num_tokens, cfg, pcount, shape, notes


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            *, force=False, quiet=False):
    mesh_name = "pod2_2x16x16" if multi_pod else "pod1_16x16"
    out_path = os.path.join(
        out_dir, f"dryrun_{arch}_{shape_name}_{mesh_name}.json")
    if os.path.exists(out_path) and not force:
        if not quiet:
            print(f"[skip-cached] {arch} {shape_name} {mesh_name}")
        return json.load(open(out_path))
    if (arch, shape_name) in SKIPS:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": SKIPS[(arch, shape_name)]}
        _write(out_path, rec)
        if not quiet:
            print(f"[skip] {arch} {shape_name}: {SKIPS[(arch, shape_name)]}")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = mesh.devices.size
    try:
        from repro.kernels import ops as kops
        from repro.sharding import set_activation_mesh
        set_activation_mesh(mesh)

        # 1. full-scale compile: sharding + memory proof (production scan)
        kops.configure(unroll=False)
        compiled, ntok, cfg, pcount, shape, notes = lower_combo(
            arch, shape_name, mesh)
        mf = analysis.model_flops(cfg, shape.kind, ntok, pcount)
        roof_full = analysis.analyze(arch, shape_name, mesh_name, compiled,
                                     ndev, mf, notes=notes)

        # 2. depth probes (unrolled) -> affine extrapolation of the
        #    roofline terms to true depth
        kops.configure(unroll=True)
        probes = []
        for npd in (1, 2):
            pc, pntok, pcfg, ppc, pshape, _ = lower_combo(
                arch, shape_name, mesh, cfg=probe_cfg(cfg, npd))
            probes.append(analysis.analyze(
                arch, shape_name, mesh_name, pc, ndev, mf))
        kops.configure(unroll=False)
        roof = analysis.extrapolate(roof_full, probes[0], probes[1],
                                    effective_periods(cfg))
        rec = roof.to_dict()
        rec.update({
            "param_count": pcount,
            "num_devices": ndev,
            "compile_seconds": round(time.time() - t0, 1),
            "skipped": None,
        })
        _write(out_path, rec)
        if not quiet:
            print(f"[ok] {arch:24s} {shape_name:12s} {mesh_name:14s} "
                  f"flops/dev={rec['flops_per_device']:.3e} "
                  f"bytes/dev={rec['bytes_per_device']:.3e} "
                  f"wire/dev={rec['wire_bytes_per_device']:.3e} "
                  f"dom={rec['dominant']:10s} "
                  f"({rec['compile_seconds']}s)")
        return rec
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()}
        _write(out_path, rec)
        print(f"[FAIL] {arch} {shape_name} {mesh_name}: "
              f"{type(e).__name__}: {e}")
        return rec


def _write(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="benchmarks/results")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if (args.both_meshes or
                               (args.all and not args.multi_pod)) \
        else [args.multi_pod]

    n_fail = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                rec = run_one(a, s, mp, args.out, force=args.force)
                if rec.get("error"):
                    n_fail += 1
    print(f"done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
