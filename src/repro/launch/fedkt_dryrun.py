import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of FedKT's OWN step — the paper's single communication round
at datacenter scale (beyond the 40 assigned pairs).

The server holds M = n*s student models stacked on the `data` axis (one
member per data-parallel group, TP over `model` within each group);
``label_step`` = vmap'd greedy prediction over the public batch + the
vocabulary-free sort-mode vote.  The cross-member vote reduction is the
paper's "one round": we count the collectives in the lowered HLO to show
the label exchange costs O(T) integers, NOT O(T * vocab) or O(M * params).

This is the LM-scale execution of the SAME protocol ``repro.federation``
drives: the lowered step is ``LMLearner.label_step`` — the exact
function the session's ``lm`` engine dispatches per partition — so the
dry-run prices the session's computation, not a parallel hand-rolled
one.  The recorded "protocol" section prices both message kinds
(PartyUpdate up, TokenLabels down) as the wire codec's MEASURED framed
bytes via ``codec.lm_protocol_bytes``.

  PYTHONPATH=src python -m repro.launch.fedkt_dryrun [--arch ...] \
      [--members 16]
"""
import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, TrainConfig, get_config
from repro.core.learners import LMLearner
from repro.federation import codec
from repro.launch import analysis
from repro.launch.dryrun import effective_periods, probe_cfg
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.sharding import set_activation_mesh
from repro.sharding.specs import (NamedSharding, P, _path_names,
                                  spec_for_param)


def member_shardings(pshapes, mesh):
    """Stacked member params: leading dim over 'data', inner spec with
    the FSDP axis dropped (each member is TP-sharded within its group)."""
    def f(kp, leaf):
        inner = spec_for_param(_path_names(kp), leaf.shape[1:], mesh)
        inner = [None if a == "data" else a for a in inner]
        return NamedSharding(mesh, P("data", *inner))
    return jax.tree_util.tree_map_with_path(f, pshapes)


def lower_label_step(arch, members, B, S, mesh, cfg=None):
    cfg = cfg or get_config(arch).replace(param_dtype="bfloat16")
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    one = jax.eval_shape(lambda: model.init(key))
    stacked = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((members,) + a.shape, a.dtype), one)
    pshard = member_shardings(stacked, mesh)
    tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
    tshard = NamedSharding(mesh, P())

    # the session engine's exact per-partition step (LMEngine dispatches
    # this same fn jitted without shardings; here it gets the mesh)
    step = LMLearner(model, TrainConfig()).label_step(members)
    jitted = jax.jit(lambda mp, t: step(mp, {"tokens": t}),
                     in_shardings=(pshard, tshard))
    return jitted.lower(stacked, tokens).compile(), cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="phi4-mini-3.8b")
    ap.add_argument("--members", type=int, default=16)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--out", default="benchmarks/results/fedkt_step.json")
    args = ap.parse_args()

    from repro.kernels import ops as kops
    mesh = make_production_mesh()
    set_activation_mesh(mesh)

    # full compile: proof + memory
    kops.configure(unroll=False)
    compiled, cfg = lower_label_step(args.arch, args.members, args.batch,
                                     args.seq, mesh)
    pcount = analysis.count_params(
        jax.eval_shape(lambda: Model(cfg).init(jax.random.PRNGKey(0))))
    mf = analysis.model_flops(cfg, "prefill",
                              args.batch * args.seq * args.members, pcount)
    full = analysis.analyze(args.arch, "fedkt_label", "pod1_16x16",
                            compiled, mesh.devices.size, mf)

    # depth probes
    kops.configure(unroll=True)
    probes = []
    for npd in (1, 2):
        pc = probe_cfg(cfg, npd)
        c, _ = lower_label_step(args.arch, args.members, args.batch,
                                args.seq, mesh, cfg=pc)
        probes.append(analysis.analyze(args.arch, "fedkt_label",
                                       "pod1_16x16", c,
                                       mesh.devices.size, mf))
    kops.configure(unroll=False)
    roof = analysis.extrapolate(full, probes[0], probes[1],
                                effective_periods(cfg))
    rec = roof.to_dict()
    rec["members"] = args.members
    # the one-round protocol cost, priced as the federation messages:
    # each member ships its state ONCE as a PartyUpdate (student state +
    # gap trace); vote labels come back as one TokenLabels message of
    # O(T) integers regardless of vocab or member count.  Sizes are the
    # wire codec's exact framed bytes (header included), computed from
    # eval_shape without materializing the member — byte-equal to
    # len(encode_*()) of the real messages (test-enforced).
    one_member = jax.eval_shape(lambda: Model(cfg).init(
        jax.random.PRNGKey(0)))
    rec["protocol"] = codec.lm_protocol_bytes(
        one_member, args.members, args.batch, args.seq)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    print(f"[fedkt-step] {args.arch} M={args.members} B={args.batch} "
          f"S={args.seq}: t_c={rec['t_compute']:.3f}s "
          f"t_m={rec['t_memory']:.3f}s t_x={rec['t_collective']:.3f}s "
          f"dom={rec['dominant']} useful={rec['useful_ratio']:.3f}")
    print("collectives:", {k: f"{v/1e9:.2f}GB"
                           for k, v in rec["collective"].items()})
    pr = rec["protocol"]
    print(f"protocol: {pr['update_bytes_per_member']/1e9:.2f}GB/member up "
          f"(once), {pr['label_bytes']/1e6:.1f}MB labels down")


if __name__ == "__main__":
    main()
