"""Training / distillation driver.

Two modes:
  - single-host (CPU tests, examples): runs real steps on jax.devices()
  - mesh mode: same step functions pjit'ed over the production mesh

Implements the LM-scale FedKT flow: train per-party teachers on private
shards, vote-label the public stream (one collective round), distill the
student, then the server-side consistent-vote + final-model distillation.

Usage (example scale):
  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
      --smoke --steps 50
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, FedKTConfig, TrainConfig, get_config,
                           get_smoke)
from repro.core.distill import make_label_step, make_train_step
from repro.core.voting import consistent_vote
from repro.data import TokenDataset, party_token_datasets, synthetic
from repro.models import Model
from repro import checkpoint


def train_lm(model: Model, dataset: TokenDataset, tcfg: TrainConfig,
             *, labels: Optional[np.ndarray] = None, params=None,
             log_every: int = 10, extra_batch: Optional[Dict] = None,
             verbose=True) -> Dict[str, Any]:
    """Plain LM (or distillation, when ``labels`` given) training loop."""
    step_fn, opt = make_train_step(model, tcfg)
    step_fn = jax.jit(step_fn)
    key = jax.random.PRNGKey(tcfg.seed)
    if params is None:
        params = model.init(key)
    opt_state = opt.init(params)

    history = []
    t0 = time.time()
    for i, batch in enumerate(dataset.batches(tcfg.batch_size,
                                              steps=tcfg.steps,
                                              labels=labels)):
        if extra_batch:
            batch = {**batch, **extra_batch}
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (i + 1) % log_every == 0 or i == 0:
            loss = float(metrics["loss"])
            history.append({"step": i + 1, "loss": loss})
            if verbose:
                print(f"  step {i+1:5d} loss {loss:.4f} "
                      f"({time.time()-t0:.1f}s)")
    return {"params": params, "history": history}


def eval_lm(model: Model, params, dataset: TokenDataset, batch_size=8,
            max_batches=8) -> float:
    losses = []
    for i, batch in enumerate(dataset.batches(batch_size,
                                              steps=max_batches)):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        losses.append(float(model.loss(params, batch, remat=False)))
    return float(np.mean(losses))


def fedkt_lm(model: Model, seqs: np.ndarray, public: np.ndarray,
             fcfg: FedKTConfig, tcfg: TrainConfig, *, verbose=True
             ) -> Dict[str, Any]:
    """LM-scale FedKT: per-token voting distillation (DESIGN.md §3)."""
    n, s, t = fcfg.num_parties, fcfg.num_partitions, fcfg.num_subsets
    parties = party_token_datasets(seqs, n, fcfg.beta, fcfg.seed)
    pub = TokenDataset(public, fcfg.seed)
    pub_tokens = jnp.asarray(public[:, :-1])
    key = jax.random.PRNGKey(fcfg.seed)

    all_students = []
    for i, pds in enumerate(parties):
        students_i = []
        for j in range(s):
            # teachers: t disjoint slices of the party's sequences
            subs = np.array_split(
                np.random.default_rng(fcfg.seed + i * 31 + j).permutation(
                    len(pds.seqs)), t)
            tp = []
            for sub in subs:
                r = train_lm(model, TokenDataset(pds.seqs[sub]), tcfg,
                             verbose=False)
                tp.append(r["params"])
            member_params = jax.tree.map(lambda *xs: jnp.stack(xs), *tp)
            label_step = jax.jit(make_label_step(
                model, t, gamma=fcfg.gamma
                if fcfg.privacy_level == "L2" else 0.0))
            key, kk = jax.random.split(key)
            labels, gap = label_step(member_params,
                                     {"tokens": pub_tokens}, kk)
            r = train_lm(model, pub, tcfg, labels=np.asarray(labels),
                         verbose=False)
            students_i.append(r["params"])
            if verbose:
                print(f"party {i} partition {j}: student distilled "
                      f"(mean vote gap {float(gap.mean()):.2f})")
        all_students.append(students_i)

    # server: consistent voting over students
    preds = jnp.stack([
        jnp.stack([model.predict(sp, {"tokens": pub_tokens})
                   for sp in si]) for si in all_students])  # (n,s,B,S)
    nn, ss, B, S = preds.shape
    key, kk = jax.random.split(key)
    vote = consistent_vote(
        preds.reshape(nn, ss, B * S), model.cfg.vocab_size,
        consistent=fcfg.consistent_voting,
        gamma=fcfg.gamma if fcfg.privacy_level == "L1" else 0.0, key=kk)
    final = train_lm(model, pub, tcfg,
                     labels=np.asarray(vote.labels).reshape(B, S),
                     verbose=False)
    return {"final_params": final["params"], "students": all_students,
            "vote": vote}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="phi4-mini-3.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--fedkt", action="store_true",
                    help="run the LM FedKT distillation flow")
    ap.add_argument("--parties", type=int, default=2)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    tcfg = TrainConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                       steps=args.steps, learning_rate=args.lr)
    data = synthetic.tokens(n_seqs=256, seq_len=args.seq_len + 1,
                            vocab=cfg.vocab_size)

    if args.fedkt:
        fcfg = FedKTConfig(num_parties=args.parties, num_partitions=2,
                           num_subsets=2, num_classes=cfg.vocab_size)
        out = fedkt_lm(model, data["train"], data["public"], fcfg, tcfg)
        params = out["final_params"]
    else:
        out = train_lm(model, TokenDataset(data["train"]), tcfg)
        params = out["params"]

    test_loss = eval_lm(model, params, TokenDataset(data["test"]))
    print(f"test loss: {test_loss:.4f}")
    if args.checkpoint:
        checkpoint.save(args.checkpoint, params,
                        metrics={"test_loss": test_loss})
        print(f"saved {args.checkpoint}")


if __name__ == "__main__":
    main()
