"""Training / distillation driver.

Two modes:
  - single-host (CPU tests, examples): runs real steps on jax.devices()
  - mesh mode: same step functions pjit'ed over the production mesh

Implements the LM-scale FedKT flow: train per-party teachers on private
shards, vote-label the public stream (one collective round), distill the
student, then the server-side consistent-vote + final-model distillation.

Usage (example scale):
  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
      --smoke --steps 50
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, FedKTConfig, TrainConfig, get_config,
                           get_smoke)
from repro.core.distill import make_train_step
from repro.data import TokenDataset, synthetic
from repro.models import Model
from repro import checkpoint


def train_lm(model: Model, dataset: TokenDataset, tcfg: TrainConfig,
             *, labels: Optional[np.ndarray] = None, params=None,
             log_every: int = 10, extra_batch: Optional[Dict] = None,
             verbose=True) -> Dict[str, Any]:
    """Plain LM (or distillation, when ``labels`` given) training loop."""
    step_fn, opt = make_train_step(model, tcfg)
    step_fn = jax.jit(step_fn)
    key = jax.random.PRNGKey(tcfg.seed)
    if params is None:
        params = model.init(key)
    opt_state = opt.init(params)

    history = []
    t0 = time.time()
    for i, batch in enumerate(dataset.batches(tcfg.batch_size,
                                              steps=tcfg.steps,
                                              labels=labels)):
        if extra_batch:
            batch = {**batch, **extra_batch}
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (i + 1) % log_every == 0 or i == 0:
            loss = float(metrics["loss"])
            history.append({"step": i + 1, "loss": loss})
            if verbose:
                print(f"  step {i+1:5d} loss {loss:.4f} "
                      f"({time.time()-t0:.1f}s)")
    return {"params": params, "history": history}


def eval_lm(model: Model, params, dataset: TokenDataset, batch_size=8,
            max_batches=8) -> float:
    losses = []
    for i, batch in enumerate(dataset.batches(batch_size,
                                              steps=max_batches)):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        losses.append(float(model.loss(params, batch, remat=False)))
    return float(np.mean(losses))


def fedkt_lm(model: Model, seqs: np.ndarray, public: np.ndarray,
             fcfg: FedKTConfig, tcfg: TrainConfig, *, test=None,
             engine: str = "lm", transport="inprocess", parallelism=None,
             verbose=True) -> Dict[str, Any]:
    """LM-scale FedKT: per-token voting distillation (DESIGN.md §3),
    driven by the SAME session stack as every other learner.

    The hand-rolled loop this function used to be is gone: an
    ``LMLearner`` wraps the distill.py label/train steps behind the
    Learner contract and ``FedKTSession`` runs the protocol — party
    split, subset plan, key schedule, wire codec and privacy accounting
    are the one session driver's (engine="lm" fuses each partition's
    predict+vote into the blocked label step; engine="loop" is the
    serial reference, bit-identical — test-enforced in
    tests/test_federation_lm.py).  ``test`` supplies held-out sequences
    for the session's next-token-accuracy metric (defaults to the
    public block).

    NOTE: exact pre-PR-5 numbers at a fixed seed are NOT preserved.
    The old loop drew teacher subsets with its own ad-hoc scheme
    (per-partition full permutations, seed + i*31 + j) and shuffled all
    student/final fits from ONE shared TokenDataset rng; the session
    uses the protocol's canonical ``subsets_of_partition`` plan
    (seed + 17*party_id, Algorithm 1 line 2) and a fresh per-fit
    shuffle stream — same distribution, reproducible per fit, and
    identical across engines/transports.
    """
    from repro.core.learners import LMLearner
    from repro.data.pipeline import lm_session_data
    from repro.federation import FedKTSession

    teacher = LMLearner(model, tcfg)
    # students and the final model distill on the public stream, which
    # the legacy loop shuffled with the federation seed
    distiller = LMLearner(model, tcfg, data_seed=fcfg.seed)
    data = lm_session_data(seqs, public,
                           public if test is None else test)
    session = FedKTSession(teacher, data, fcfg,
                           student_learner=distiller,
                           final_learner=distiller, engine=engine,
                           transport=transport, parallelism=parallelism)
    res = session.run(verbose=verbose)
    if verbose:
        print(f"fedkt-lm [{res.meta['engine']}]: next-token acc "
              f"{res.accuracy:.4f}, "
              f"{res.meta['wire_bytes']['updates']} update wire bytes")
    return {"final_params": res.final_state,
            "students": res.student_states, "result": res}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="phi4-mini-3.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--fedkt", action="store_true",
                    help="run the LM FedKT distillation flow")
    ap.add_argument("--parties", type=int, default=2)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    tcfg = TrainConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                       steps=args.steps, learning_rate=args.lr)
    data = synthetic.tokens(n_seqs=256, seq_len=args.seq_len + 1,
                            vocab=cfg.vocab_size)

    if args.fedkt:
        fcfg = FedKTConfig(num_parties=args.parties, num_partitions=2,
                           num_subsets=2, num_classes=cfg.vocab_size)
        out = fedkt_lm(model, data["train"], data["public"], fcfg, tcfg,
                       test=data["test"])
        params = out["final_params"]
    else:
        out = train_lm(model, TokenDataset(data["train"]), tcfg)
        params = out["params"]

    test_loss = eval_lm(model, params, TokenDataset(data["test"]))
    print(f"test loss: {test_loss:.4f}")
    if args.checkpoint:
        checkpoint.save(args.checkpoint, params,
                        metrics={"test_loss": test_loss})
        print(f"saved {args.checkpoint}")


if __name__ == "__main__":
    main()
