"""Cross-silo federation launcher: one FedKT round over real sockets.

Three roles, sharing one seeded setup (data, partition, key schedule),
so a round split across OS processes — or hosts — reproduces the
in-process session seed-for-seed:

  local        : the whole fleet on this host.  FedKTSession with the
                 socket transport; parties are simulated on a thread
                 pool and deliver over localhost TCP.

  coordinator  : the server side only (``SocketTransport(spawn=False)``).
                 Binds host:port and waits for remote parties, folding
                 each arriving update into the streaming vote aggregate;
                 proceeds at quorum when the deadline passes.

  party        : one silo.  Rebuilds ITS shard and starting key from
                 the shared seed, runs the local round, ships the one
                 PartyUpdate to the coordinator (connect retries with
                 exponential backoff baked in).

Crash safety: ``--journal PATH`` makes the coordinator write-ahead
journal every accepted frame (fsync'd before the ACK), and
``--resume`` replays that journal after a crash — the restarted round
refolds the already-delivered parties and waits only for the missing
ones, so no silo ever retrains because the server died.  ``--chaos``
(with ``--chaos-seed``) runs the local fleet through a seeded
fault-injection proxy — corrupted frames, killed connections, dropped
ACKs, duplicate deliveries — as a soak of exactly those guarantees;
the faults that fired are reported under ``"chaos"``.

Every role accepts ``--learner`` (uniform model family: nn | rf |
gbdt) or ``--learners rf,gbdt,nn,...`` (one kind per party) — a real
TCP fleet can mix tree and neural silos in one round because the vote
DOMAIN (federation/domain.py) is the only cross-party contract.  All
roles must pass the SAME roster: the coordinator needs it to bind each
arriving update to its student learner.  ``--vertical`` switches the
round to feature-split silos: every party holds ALL samples and a
disjoint column slice (core.partition.vertical_split), trains a
feature-masked learner, and votes in the shared example domain — see
examples/vertical_fedkt.py for the annotated walkthrough.

Demo (two shells):
  PYTHONPATH=src python -m repro.launch.federate coordinator \
      --parties 4 --port 7733 --deadline-s 120 --min-parties 3
  for i in 0 1 2 3; do PYTHONPATH=src python -m repro.launch.federate \
      party --party-id $i --parties 4 --port 7733 & done

See docs/federation.md for the deployment guide (timeout/quorum knobs,
dropout accounting, wire-byte pricing).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.base import FedKTConfig
from repro.core.learners import GBDTLearner, NNLearner, RFLearner
from repro.core.partition import vertical_split
from repro.data.synthetic import tabular_binary
from repro.federation import (FedKTSession, PartyBinding, SocketTransport,
                              party_starting_keys, query_budget,
                              run_party_client)
from repro.federation.bindings import registered_learner_kinds
from repro.models.smallnets import MLP

LEARNER_KINDS = ("nn", "rf", "gbdt")
NUM_FEATURES = 14          # tabular_binary's fixed feature width


def build_learner(kind: str, args, feature_mask=None):
    """One learner instance for a party role.  The same --seed plus the
    same kind list must rebuild identical learners on every host, so
    all hyperparameters come from CLI flags (never from local state).
    ``feature_mask`` (a sorted column-index tuple from
    ``vertical_split``) builds the vertical variant: the learner trains
    and predicts on only its silo's feature slice."""
    nfeat = NUM_FEATURES if feature_mask is None else len(feature_mask)
    if kind == "nn":
        return NNLearner(MLP(num_features=nfeat, num_classes=2,
                             hidden=args.hidden),
                         num_classes=2, steps=args.steps,
                         feature_mask=feature_mask)
    if kind == "rf":
        return RFLearner(num_classes=2, num_trees=args.trees,
                         depth=args.depth, feature_mask=feature_mask)
    if kind == "gbdt":
        return GBDTLearner(num_classes=2, num_rounds=args.trees,
                           depth=args.depth, feature_mask=feature_mask)
    raise ValueError(f"unknown learner kind {kind!r}; "
                     f"available: {list(LEARNER_KINDS)}")


def party_kinds(args):
    """The fleet's learner-kind roster, one entry per party.  --learners
    (comma list) pins each silo's model family; --learner is the uniform
    default.  Every role — coordinator included — derives the SAME
    roster, because the server must know which student learner answers
    each party's update.  A kind this launcher cannot build fails HERE
    — up front, naming the offending party — not as a stray exception
    mid-round on some host."""
    if args.learners:
        kinds = [k.strip() for k in args.learners.split(",")]
        if len(kinds) != args.parties:
            raise SystemExit(f"--learners names {len(kinds)} kinds but "
                             f"--parties is {args.parties}")
        for i, k in enumerate(kinds):
            if k not in LEARNER_KINDS:
                raise SystemExit(
                    f"--learners: unknown learner kind {k!r} for party "
                    f"{i}; this launcher builds {list(LEARNER_KINDS)} "
                    f"(registered wire kinds: "
                    f"{registered_learner_kinds()})")
        return kinds
    return [args.learner] * args.parties


def build_session(args, transport) -> FedKTSession:
    """The shared seeded setup: every role derives the same data,
    partition, key schedule, and per-party learner bindings from the
    CLI flags, so the only thing that differs between roles is WHERE
    each piece runs."""
    data = tabular_binary(n=args.n_train, seed=args.seed)
    kinds = party_kinds(args)
    cfg = FedKTConfig(num_parties=args.parties,
                      num_partitions=args.partitions,
                      num_subsets=args.subsets, num_classes=2,
                      privacy_level=args.privacy, gamma=args.gamma,
                      seed=args.seed)
    if args.vertical:
        # feature-split silos: every party holds ALL samples (aligned
        # by the shared sample-id vector — here the synthetic row ids)
        # and a disjoint column slice; its learner is feature-masked,
        # so raw off-silo columns never cross the boundary.  The final
        # model distills on the full-width public queries.
        row_order, masks = vertical_split(
            np.arange(len(data["X_train"])), NUM_FEATURES, args.parties,
            seed=args.seed)
        bindings = [PartyBinding(build_learner(k, args, feature_mask=m),
                                 engine=args.engine)
                    for k, m in zip(kinds, masks)]
        indices = [row_order.copy() for _ in range(args.parties)]
        return FedKTSession(bindings, data, cfg, engine=args.engine,
                            final_learner=build_learner("nn", args),
                            party_indices=indices, transport=transport,
                            retain_students=not args.drop_students)
    if len(set(kinds)) == 1:
        # homogeneous shorthand: identical to the pre-binding launcher
        return FedKTSession(build_learner(kinds[0], args), data, cfg,
                            engine=args.engine, transport=transport,
                            retain_students=not args.drop_students)
    bindings = [PartyBinding(build_learner(k, args), engine=args.engine)
                for k in kinds]
    # mixed fleets distill the final model with an NN student on the
    # server (any kind works; the vote labels are learner-agnostic)
    return FedKTSession(bindings, data, cfg, engine=args.engine,
                        final_learner=build_learner("nn", args),
                        transport=transport,
                        retain_students=not args.drop_students)


def _report(result) -> None:
    sock = result.meta.get("socket", {})
    out = {
        "accuracy": round(float(result.accuracy), 4),
        "epsilon": result.epsilon,
        "arrived": len(sock.get("arrived", [])),
        "dropped_parties": result.meta.get("dropped_parties", []),
        "wire_bytes": result.meta["wire_bytes"],
        "seconds": result.meta["seconds"],
    }
    if sock.get("journal"):
        out["journal"] = sock["journal"]
        out["resumed"] = sock.get("resumed", False)
        out["replayed_parties"] = sock.get("replayed_parties", [])
        out["corrupt_records_dropped"] = \
            sock.get("corrupt_records_dropped", 0)
        out["re_acked"] = sock.get("re_acked", {})
    if "chaos" in sock:
        out["chaos"] = sock["chaos"]
    print(json.dumps(out, indent=1))


def _chaos_plan(args):
    """The local soak's seeded fault schedule: enough scripted faults
    to cover every party a few times over (retransmits get their own
    connection ordinals), reproducible from --chaos-seed."""
    if not args.chaos:
        return None
    from repro.federation.faults import FaultPlan
    return FaultPlan.random(args.chaos_seed, 3 * args.parties)


def run_local(args) -> None:
    transport = SocketTransport(parallelism=args.parallelism,
                                port=args.port,
                                deadline_s=args.deadline_s,
                                min_parties=args.min_parties,
                                journal_path=args.journal,
                                resume=args.resume,
                                chaos_plan=_chaos_plan(args))
    result = build_session(args, transport).run(verbose=args.verbose)
    _report(result)


def run_coordinator(args) -> None:
    transport = SocketTransport(host=args.host, port=args.port,
                                spawn=False,
                                deadline_s=args.deadline_s,
                                min_parties=args.min_parties,
                                journal_path=args.journal,
                                resume=args.resume)
    print(f"coordinator: waiting for {args.parties} parties on "
          f"{args.host}:{args.port} (deadline "
          f"{args.deadline_s}s, quorum "
          f"{args.min_parties or args.parties})"
          + (f"; journaling to {args.journal}"
             + (" [resume]" if args.resume else "")
             if args.journal else ""))
    result = build_session(args, transport).run(verbose=args.verbose)
    _report(result)


def run_party(args) -> None:
    session = build_session(args, "inprocess")   # setup only, never run
    keys, _ = party_starting_keys(session.parties, args.seed)
    party = session.parties[args.party_id]
    tq_party, _ = query_budget(session.cfg,
                               len(session.data["X_public"]))
    nbytes = run_party_client(
        args.host, args.port, party, keys[args.party_id],
        session.data["X_public"], tq_party, engine=None,
        retries=args.retries, backoff_s=args.backoff_s)
    kind = session.bindings[args.party_id].kind
    print(f"party {args.party_id} ({kind}): update delivered to "
          f"{args.host}:{args.port} ({nbytes} framed bytes)")


def main():
    ap = argparse.ArgumentParser(
        description="one FedKT round over TCP sockets")
    ap.add_argument("role", choices=["local", "coordinator", "party"])
    ap.add_argument("--parties", type=int, default=4)
    ap.add_argument("--partitions", type=int, default=1)
    ap.add_argument("--subsets", type=int, default=2)
    ap.add_argument("--n-train", type=int, default=4096)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--learner", default="nn", choices=LEARNER_KINDS,
                    help="model family every party trains (uniform "
                         "default; see --learners for mixed fleets)")
    ap.add_argument("--learners", default=None,
                    help="comma list, one kind per party (e.g. "
                         "'rf,gbdt,nn,nn') — every role must pass the "
                         "same list so the server binds each silo's "
                         "update to its learner")
    ap.add_argument("--trees", type=int, default=20,
                    help="rf: trees per forest / gbdt: boosting rounds")
    ap.add_argument("--depth", type=int, default=6,
                    help="rf/gbdt tree depth")
    ap.add_argument("--engine", default="loop")
    ap.add_argument("--privacy", default="L0",
                    choices=["L0", "L1", "L2"])
    ap.add_argument("--gamma", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7733)
    ap.add_argument("--parallelism", type=int, default=None,
                    help="local role: concurrent simulated parties")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-party deadline from round start")
    ap.add_argument("--min-parties", type=int, default=None,
                    help="quorum: proceed at the deadline with at "
                         "least this many updates")
    ap.add_argument("--vertical", action="store_true",
                    help="feature-split silos: every party holds all "
                         "samples and a disjoint slice of the feature "
                         "columns (core.partition.vertical_split); "
                         "works in every role — remote parties rebuild "
                         "the same masks from --seed")
    ap.add_argument("--drop-students", action="store_true",
                    help="fold-and-drop updates (constant server "
                         "memory; RoundResult carries no student "
                         "states)")
    ap.add_argument("--journal", default=None,
                    help="local/coordinator: write-ahead journal file; "
                         "every accepted update is fsync'd here before "
                         "it is ACKed, so a crashed round resumes")
    ap.add_argument("--resume", action="store_true",
                    help="replay an existing --journal: refold the "
                         "already-delivered parties and wait only for "
                         "the missing ones")
    ap.add_argument("--chaos", action="store_true",
                    help="local role: route party deliveries through a "
                         "seeded fault-injection proxy (corrupt / kill "
                         "/ delay / duplicate / dropped-ACK) — a soak "
                         "of the crash-safety layer")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the --chaos fault schedule (same "
                         "seed, same faults)")
    ap.add_argument("--retries", type=int, default=8,
                    help="party role: connect attempts")
    ap.add_argument("--backoff-s", type=float, default=0.05,
                    help="party role: base exponential backoff")
    ap.add_argument("--party-id", type=int, default=0,
                    help="party role: which silo this process is")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()
    {"local": run_local, "coordinator": run_coordinator,
     "party": run_party}[args.role](args)


if __name__ == "__main__":
    main()
