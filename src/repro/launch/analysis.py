"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
memory term     = HLO_bytes_per_device / HBM_bw_per_chip
collective term = collective_bytes_per_device / ICI_link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (the post-SPMD
per-device module).  Collective bytes are parsed from the HLO text:
for each all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute we take the op's *result* bytes, with a 2x factor for
all-reduce (ring: reduce-scatter + all-gather pass) — a documented
first-order wire-traffic model.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes in the (per-device) module."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        lhs, _, rhs = ls.partition("=")
        rhs = rhs.strip()
        kind = next((k for k in _COLLECTIVES
                     if rhs.startswith(k + "(")
                     or re.match(rf"\(?[a-z0-9]+\[[0-9,]*\].*\)?\s*{k}\(",
                                 rhs)), None)
        if kind is None:
            # rhs looks like "bf16[2048]{0} all-reduce(...)"
            m = re.match(r"[^a-z]*(?:\(?)([a-z0-9]+\[[0-9,]*\][^ ]*(?:, "
                         r"[a-z0-9]+\[[0-9,]*\][^ ]*)*)\)?\s+([a-z-]+)\(",
                         rhs)
            if not m or m.group(2) not in _COLLECTIVES:
                continue
            kind = m.group(2)
            shapes = m.group(1)
        else:
            shapes = rhs.split(kind + "(")[0]
        out[kind] += sum(_shape_bytes(m)
                         for m in _SHAPE_RE.finditer(shapes))
    return out


def wire_bytes(coll: Dict[str, int]) -> int:
    """First-order per-chip wire traffic."""
    return (2 * coll["all-reduce"] + coll["all-gather"]
            + coll["reduce-scatter"] + coll["all-to-all"]
            + coll["collective-permute"])


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective: Dict[str, int]
    wire_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops_total: float
    useful_ratio: float
    peak_memory_bytes: Optional[float] = None
    num_devices: int = 1
    notes: str = ""

    def to_dict(self):
        return asdict(self)


def analyze(arch, shape, mesh_name, compiled, num_devices,
            model_flops_total, notes="") -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    wb = wire_bytes(coll)

    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = wb / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]

    peak_mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            peak_mem = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass

    useful = model_flops_total / max(flops * num_devices, 1.0)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name,
        flops_per_device=flops, bytes_per_device=byts, collective=coll,
        wire_bytes_per_device=wb, t_compute=t_c, t_memory=t_m,
        t_collective=t_x, dominant=dom,
        model_flops_total=model_flops_total, useful_ratio=useful,
        peak_memory_bytes=peak_mem, num_devices=num_devices, notes=notes)


def extrapolate(full: Roofline, p1: Roofline, p2: Roofline,
                eff_periods: float) -> Roofline:
    """Affine depth extrapolation: X_true = X(1) + (P-1) * (X(2) - X(1)).

    The probes compile with every chunk/period scan unrolled, so their
    cost analysis sees all bodies; the full compile contributes only the
    memory proof (peak bytes from the production scan program).
    """
    def ext(a, b):
        # costs are monotone in depth; negative deltas are fusion noise
        # on tiny probes — clamp
        return a + (eff_periods - 1.0) * max(0.0, b - a)

    flops = ext(p1.flops_per_device, p2.flops_per_device)
    byts = ext(p1.bytes_per_device, p2.bytes_per_device)
    coll = {k: int(max(0.0, ext(p1.collective[k], p2.collective[k])))
            for k in p1.collective}
    wb = wire_bytes(coll)
    t_c, t_m, t_x = flops / PEAK_FLOPS, byts / HBM_BW, wb / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    return Roofline(
        arch=full.arch, shape=full.shape, mesh=full.mesh,
        flops_per_device=flops, bytes_per_device=byts, collective=coll,
        wire_bytes_per_device=wb, t_compute=t_c, t_memory=t_m,
        t_collective=t_x, dominant=dom,
        model_flops_total=full.model_flops_total,
        useful_ratio=full.model_flops_total / max(flops * full.num_devices,
                                                  1.0),
        peak_memory_bytes=full.peak_memory_bytes,
        num_devices=full.num_devices,
        notes=full.notes)


def count_params(shape_tree, exclude_embed=True) -> int:
    import jax
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shape_tree)[0]:
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if exclude_embed and "embed" in keys:
            continue
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    return total


def model_flops(cfg, shape_kind: str, num_tokens: int,
                param_count: int) -> float:
    """6*N*D for training, 2*N*D for inference forward (per step);
    N = active params (MoE: top_k/num_experts of expert params +
    the rest)."""
    n_active = param_count
    if cfg.moe is not None:
        # expert params scale by activation ratio
        m = cfg.moe
        frac = (m.top_k + m.num_shared_experts) / (
            m.num_experts + m.num_shared_experts)
        # crude split: experts hold most FFN params
        e_params = (cfg.num_layers * m.num_experts * cfg.d_ff
                    * cfg.d_model * (3 if cfg.mlp in ("swiglu", "geglu")
                                     else 2))
        n_active = param_count - e_params + e_params * frac
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n_active * num_tokens
