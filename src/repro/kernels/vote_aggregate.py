"""PATE vote-aggregation Pallas kernel — the paper's core operation.

Given M teacher predictions for T queries, computes per query the (noisy)
max-vote label plus the top-2 vote scores (needed by consistent voting and
by the Lemma-7 privacy bound q = Pr[M(d) != o*]).

The paper's setting has u <= 10 classes; scaled to per-token LM voting the
class axis is the vocabulary (32k-256k), so a dense (T, U) histogram never
fits on chip.  TPU-native reformulation: the grid walks (query-block,
class-block) with the class axis innermost; each step histogram-counts the
M teacher votes that fall inside the current class block (rank-1 compares
on the VPU, no HBM histogram), adds the Laplace noise block, and folds the
block's top-2 into running (best, second, argbest) VMEM accumulators.
Output is O(T), not O(T*U).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _block_top2(scores, bt, bu):
    """(m1 (bt,1), argmax (bt,), m2 (bt,1)) of one class block.  Masks
    only the argmax POSITION (not every equal value), so exact ties
    yield m2 == m1 — matching the xla path's one_hot masking and the
    top_k semantics the Lemma-7 gap needs on clean integer counts."""
    m1 = jnp.max(scores, axis=1, keepdims=True)                  # (bt,1)
    i1 = jnp.argmax(scores, axis=1).astype(jnp.int32)            # (bt,)
    pos = jax.lax.broadcasted_iota(jnp.int32, (bt, bu), 1)
    masked = jnp.where(pos == i1[:, None], NEG_INF, scores)
    m2 = jnp.max(masked, axis=1, keepdims=True)
    return m1, i1, m2


def _fold_top2(best, second, m1, m2):
    """Fold one block's (m1, m2) into running (best, second).  Returns
    (take, new_best, new_second); strictly-greater keeps the
    first-occurrence argmax."""
    take = m1 > best
    new_best = jnp.where(take, m1, best)
    new_second = jnp.maximum(jnp.where(take, best, m1), second)
    new_second = jnp.maximum(new_second, jnp.where(take, m2, NEG_INF))
    return take, new_best, new_second


def _kernel(preds_ref, noise_ref, label_ref, top1_ref, top2_ref,
            clean1_ref, clean2_ref, best_ref, second_ref, argbest_ref,
            cbest_ref, csecond_ref, *, M, bt, bu, nu):
    iu = pl.program_id(1)

    @pl.when(iu == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, NEG_INF)
        second_ref[...] = jnp.full_like(second_ref, NEG_INF)
        argbest_ref[...] = jnp.zeros_like(argbest_ref)
        cbest_ref[...] = jnp.full_like(cbest_ref, NEG_INF)
        csecond_ref[...] = jnp.full_like(csecond_ref, NEG_INF)

    class_base = iu * bu
    ids = class_base + jax.lax.broadcasted_iota(jnp.int32, (bt, bu), 1)

    def count_one(m, counts):
        p = preds_ref[m, :]                       # (bt,)
        return counts + (p[:, None] == ids).astype(jnp.float32)

    counts = jax.lax.fori_loop(
        0, M, count_one, jnp.zeros((bt, bu), jnp.float32))

    # clean top-2 (pre-noise): the privacy accountant's gap input, from
    # the SAME histogram the noisy argmax consumes
    cm1, _, cm2 = _block_top2(counts, bt, bu)
    _, cbest, csecond = _fold_top2(cbest_ref[...], csecond_ref[...],
                                   cm1, cm2)
    cbest_ref[...] = cbest
    csecond_ref[...] = csecond

    # noisy top-2 of this class block
    scores = counts + noise_ref[...].astype(jnp.float32)
    m1, i1, m2 = _block_top2(scores, bt, bu)
    take, new_best, new_second = _fold_top2(best_ref[...], second_ref[...],
                                            m1, m2)
    argbest_ref[...] = jnp.where(
        take[:, 0], class_base + i1, argbest_ref[...])
    best_ref[...] = new_best
    second_ref[...] = new_second

    @pl.when(iu == nu - 1)
    def _final():
        label_ref[...] = argbest_ref[...]
        top1_ref[...] = best_ref[...][:, 0]
        top2_ref[...] = second_ref[...][:, 0]
        clean1_ref[...] = cbest_ref[...][:, 0]
        clean2_ref[...] = csecond_ref[...][:, 0]


@functools.partial(jax.jit, static_argnames=(
    "num_classes", "block_t", "block_u", "interpret"))
def vote_aggregate(preds, noise, *, num_classes, block_t=128, block_u=512,
                   interpret=False):
    """preds: (M, T) int32; noise: (T, U) float32 (zeros for L0).

    Returns (labels (T,) int32, top1 (T,) f32, top2 (T,) f32,
    clean_top1 (T,) f32, clean_top2 (T,) f32) — the noisy argmax stats
    plus the pre-noise top-2 from the same single histogram pass.
    """
    M, T = preds.shape
    U = num_classes
    bt, bu = min(block_t, T), min(block_u, U)
    assert T % bt == 0 and U % bu == 0, (T, U, bt, bu)
    nt, nu = T // bt, U // bu

    kern = functools.partial(_kernel, M=M, bt=bt, bu=bu, nu=nu)
    return pl.pallas_call(
        kern,
        grid=(nt, nu),
        in_specs=[
            pl.BlockSpec((M, bt), lambda it, iu: (0, it)),
            pl.BlockSpec((bt, bu), lambda it, iu: (it, iu)),
        ],
        out_specs=[pl.BlockSpec((bt,), lambda it, iu: (it,))
                   for _ in range(5)],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt, 1), jnp.float32),   # best
            pltpu.VMEM((bt, 1), jnp.float32),   # second
            pltpu.VMEM((bt,), jnp.int32),       # argbest
            pltpu.VMEM((bt, 1), jnp.float32),   # clean best
            pltpu.VMEM((bt, 1), jnp.float32),   # clean second
        ],
        interpret=interpret,
    )(preds, noise)
