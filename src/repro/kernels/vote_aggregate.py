"""PATE vote-aggregation Pallas kernel — the paper's core operation.

Given M teacher predictions for T queries, computes per query the (noisy)
max-vote label plus the top-2 vote scores (needed by consistent voting and
by the Lemma-7 privacy bound q = Pr[M(d) != o*]).

The paper's setting has u <= 10 classes; scaled to per-token LM voting the
class axis is the vocabulary (32k-256k), so a dense (T, U) histogram never
fits on chip.  TPU-native reformulation: the grid walks (query-block,
class-block) with the class axis innermost; each step histogram-counts the
M teacher votes that fall inside the current class block (rank-1 compares
on the VPU, no HBM histogram), adds the Laplace noise block, and folds the
block's top-2 into running (best, second, argbest) VMEM accumulators.
Output is O(T), not O(T*U).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(preds_ref, noise_ref, label_ref, top1_ref, top2_ref,
            best_ref, second_ref, argbest_ref, *, M, bt, bu, nu):
    iu = pl.program_id(1)

    @pl.when(iu == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, NEG_INF)
        second_ref[...] = jnp.full_like(second_ref, NEG_INF)
        argbest_ref[...] = jnp.zeros_like(argbest_ref)

    class_base = iu * bu
    ids = class_base + jax.lax.broadcasted_iota(jnp.int32, (bt, bu), 1)

    def count_one(m, counts):
        p = preds_ref[m, :]                       # (bt,)
        return counts + (p[:, None] == ids).astype(jnp.float32)

    counts = jax.lax.fori_loop(
        0, M, count_one, jnp.zeros((bt, bu), jnp.float32))
    scores = counts + noise_ref[...].astype(jnp.float32)

    # top-2 of this class block
    m1 = jnp.max(scores, axis=1, keepdims=True)                  # (bt,1)
    i1 = jnp.argmax(scores, axis=1).astype(jnp.int32)            # (bt,)
    masked = jnp.where(scores == m1, NEG_INF, scores)
    m2 = jnp.max(masked, axis=1, keepdims=True)

    best, second = best_ref[...], second_ref[...]
    m1_ = m1
    take = m1_ > best          # strictly greater: first-occurrence argmax
    new_best = jnp.where(take, m1_, best)
    new_second = jnp.maximum(jnp.where(take, best, m1_), second)
    new_second = jnp.maximum(new_second, jnp.where(take, m2, NEG_INF))
    argbest_ref[...] = jnp.where(
        take[:, 0], class_base + i1, argbest_ref[...])
    best_ref[...] = new_best
    second_ref[...] = new_second

    @pl.when(iu == nu - 1)
    def _final():
        label_ref[...] = argbest_ref[...]
        top1_ref[...] = best_ref[...][:, 0]
        top2_ref[...] = second_ref[...][:, 0]


@functools.partial(jax.jit, static_argnames=(
    "num_classes", "block_t", "block_u", "interpret"))
def vote_aggregate(preds, noise, *, num_classes, block_t=128, block_u=512,
                   interpret=False):
    """preds: (M, T) int32; noise: (T, U) float32 (zeros for L0).

    Returns (labels (T,) int32, top1 (T,) f32, top2 (T,) f32).
    """
    M, T = preds.shape
    U = num_classes
    bt, bu = min(block_t, T), min(block_u, U)
    assert T % bt == 0 and U % bu == 0, (T, U, bt, bu)
    nt, nu = T // bt, U // bu

    kern = functools.partial(_kernel, M=M, bt=bt, bu=bu, nu=nu)
    return pl.pallas_call(
        kern,
        grid=(nt, nu),
        in_specs=[
            pl.BlockSpec((M, bt), lambda it, iu: (0, it)),
            pl.BlockSpec((bt, bu), lambda it, iu: (it, iu)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda it, iu: (it,)),
            pl.BlockSpec((bt,), lambda it, iu: (it,)),
            pl.BlockSpec((bt,), lambda it, iu: (it,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt, 1), jnp.float32),   # best
            pltpu.VMEM((bt, 1), jnp.float32),   # second
            pltpu.VMEM((bt,), jnp.int32),       # argbest
        ],
        interpret=interpret,
    )(preds, noise)
