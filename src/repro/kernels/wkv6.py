"""RWKV-6 (Finch) WKV recurrence Pallas kernel.

Per head, per step:
    o_t = r_t . (S + (u * k_t) v_t^T)
    S  <- diag(w_t) S + k_t v_t^T
with data-dependent decay w_t in (0,1) and a (dh, dh) matrix state S.

TPU formulation: grid (B, H, time-block) with time innermost; the (dh, dh)
f32 state lives in VMEM scratch and carries across time blocks, so HBM
traffic is one pass over (r, k, v, w) and one write of o.  dh = 64 means
the state is a single (64, 64) VREG-friendly tile; the in-chunk loop runs
rank-1 updates on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, slast_ref,
            s_ref, *, bs, ns):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)          # (bs, dh)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)             # (dh,)

    def step(t, s):
        kv = k[t][:, None] * v[t][None, :]       # (dh_k, dh_v)
        o = jnp.sum(r[t][:, None] * (s + u[:, None] * kv), axis=0)
        o_ref[0, 0, t, :] = o.astype(o_ref.dtype)
        return w[t][:, None] * s + kv

    s = jax.lax.fori_loop(0, bs, step, s_ref[...])
    s_ref[...] = s

    @pl.when(it == ns - 1)
    def _final():
        slast_ref[0, 0] = s.astype(slast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def wkv6(r, k, v, w, u, s0, *, block_s=256, interpret=False):
    """r/k/v/w: (B, H, S, dh); u: (H, dh); s0: (B, H, dh, dh).

    Returns (o (B,H,S,dh), s_last (B,H,dh,dh) float32).
    """
    B, H, S, dh = r.shape
    bs = min(block_s, S)
    assert S % bs == 0
    ns = S // bs

    kern = functools.partial(_kernel, bs=bs, ns=ns)
    return pl.pallas_call(
        kern,
        grid=(B, H, ns),
        in_specs=[
            pl.BlockSpec((1, 1, bs, dh), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, bs, dh), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, bs, dh), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, bs, dh), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, dh), lambda b, h, it: (h, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, it: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bs, dh), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, it: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, dh), r.dtype),
            jax.ShapeDtypeStruct((B, H, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
