"""RG-LRU linear-recurrence Pallas kernel (RecurrentGemma / Griffin).

Computes h_t = exp(log_a_t) * h_{t-1} + x_t along the sequence axis.
This is the serial bottleneck of the recurrent blocks; the TPU-native
formulation chunks time into VMEM-resident blocks: the grid walks
(batch, d-block, time-block) with the time axis innermost so the hidden
state carries across grid steps in VMEM scratch — HBM traffic is exactly
one read of (x, log_a) and one write of h, with no state round-trips.

Channel blocks are 128-lane aligned; the in-chunk recurrence runs on the
VPU via fori_loop over the (bs) time steps of the chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, h0_ref, h_ref, hlast_ref, carry_ref, *, bs, ns):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)   # (1, bd) -> (bd,)

    x = x_ref[0].astype(jnp.float32)                     # (bs, bd)
    a = a_ref[0].astype(jnp.float32)

    def step(t, h):
        h = jnp.exp(a[t]) * h + x[t]
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, carry_ref[...])
    carry_ref[...] = h

    @pl.when(it == ns - 1)
    def _final():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "block_d", "interpret"))
def rglru_scan(x, log_a, h0, *, block_s=256, block_d=256, interpret=False):
    """x, log_a: (B, S, D); h0: (B, D).  Returns (h (B,S,D), h_last (B,D))."""
    B, S, D = x.shape
    bs, bd = min(block_s, S), min(block_d, D)
    assert S % bs == 0 and D % bd == 0
    ns, nd = S // bs, D // bd

    kern = functools.partial(_kernel, bs=bs, ns=ns)
    return pl.pallas_call(
        kern,
        grid=(B, nd, ns),
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda b, id_, it: (b, it, id_)),
            pl.BlockSpec((1, bs, bd), lambda b, id_, it: (b, it, id_)),
            pl.BlockSpec((1, bd), lambda b, id_, it: (b, id_)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bd), lambda b, id_, it: (b, it, id_)),
            pl.BlockSpec((1, bd), lambda b, id_, it: (b, id_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), x.dtype),
            jax.ShapeDtypeStruct((B, D), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        interpret=interpret,
    )(x, log_a, h0)
