"""Public jit'd wrappers around the Pallas kernels.

Every op has three implementations selected by ``impl``:
  - "kernel":            pl.pallas_call, TPU target
  - "kernel_interpret":  same kernel body executed in interpret mode
                         (CPU correctness validation)
  - "xla":               memory-bounded pure-jnp formulation (chunked /
                         associative-scan) used for CPU lowering & dry-run
"auto" resolves to "kernel" on TPU backends and "xla" elsewhere, so model
code calls one API everywhere.

The xla paths are *not* the naive oracles from ref.py: they are written to
bound peak memory (chunked q-block attention with rematerialized chunks,
associative-scan recurrences) so that the 32k-prefill dry-runs fit HBM.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rglru_scan as _rg
from repro.kernels import tree_hist as _th
from repro.kernels import vote_aggregate as _va
from repro.kernels import wkv6 as _wk
from repro.kernels import ref

NEG_INF = -1e30

# Global chunking knobs.  The dry-run sets unroll=True so XLA cost
# analysis sees every chunk body (HloCostAnalysis counts a while body
# once regardless of trip count — measured, see EXPERIMENTS.md §Dry-run).
CONFIG = {"block_q": 512, "unroll": False}


def configure(**kw):
    CONFIG.update(kw)


def resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "kernel" if jax.default_backend() == "tpu" else "xla"


def _pad_to(x, mult, axis):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


# ---------------------------------------------------------------------------
# Attention  (model-facing layout: (B, S, H, dh))
# ---------------------------------------------------------------------------
def attention(q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0,
              impl="auto", block_q=None):
    """Softmax attention.  q: (B,Sq,H,dh), k/v: (B,Skv,KV,dh).

    ``q_offset`` is the absolute position of q[:, 0]: a scalar, or a
    (B,) int32 vector of per-row offsets (continuous-batching decode —
    each batch row is an independent stream at its own position).
    Vector offsets are a decode-path feature: they require Sq == 1 and
    always take the xla path (the flash kernel's offset is scalar)."""
    per_row_offset = getattr(q_offset, "ndim", 0) == 1
    if per_row_offset and q.shape[1] != 1:
        raise NotImplementedError(
            "per-row q_offset is only supported for single-token decode "
            f"(Sq == 1); got Sq={q.shape[1]}")
    if block_q is None:
        # cap the chunk count so unrolled counting stays compile-cheap
        block_q = max(CONFIG["block_q"], q.shape[1] // 16)
    impl = resolve_impl(impl)
    if impl == "xla" or q.shape[1] == 1:
        return _attention_xla(q, k, v, causal=causal, window=window,
                              softcap=softcap, q_offset=q_offset,
                              block_q=block_q)
    interpret = impl == "kernel_interpret"
    # kernel layout (B, H, S, dh)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq, bk = 256, 512
    qt, sq = _pad_to(qt, bq, 2)
    kt, _ = _pad_to(kt, bk, 2)
    vt, _ = _pad_to(vt, bk, 2)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              softcap=softcap, q_offset=q_offset,
                              block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :, :sq].transpose(0, 2, 1, 3)


def _attention_xla(q, k, v, *, causal, window, softcap, q_offset, block_q):
    B, Sq, H, dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    g = H // KV

    def chunk_attn(q_blk, base):
        # q_blk: (B, bq, H, dh); base: absolute position of q_blk[0]
        bqn = q_blk.shape[1]
        qf = q_blk.astype(jnp.float32) * (dh ** -0.5)
        kf = k.astype(jnp.float32)
        # GQA: fold group into head dim without materializing repeats
        qf = qf.reshape(B, bqn, KV, g, dh)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf)       # (B,KV,g,bq,Skv)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        # qpos: (bqn,) for a scalar base, (B, bqn) for per-row offsets
        qpos = jnp.asarray(base)[..., None] + jnp.arange(bqn)
        kpos = jnp.arange(Skv)
        m = jnp.ones(qpos.shape + (Skv,), bool)
        if causal:
            m &= kpos <= qpos[..., None]
        if window > 0:
            m &= kpos > qpos[..., None] - window
        s = jnp.where(m[:, None, None] if m.ndim == 3
                      else m[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        # probs in compute dtype for the PV matmul (flash-kernel practice;
        # halves the dominant attention HBM term — §Perf iter 6)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, bqn, H, dh).astype(q.dtype)

    if Sq <= block_q:
        return chunk_attn(q, q_offset)

    bq = block_q
    nq, rem = divmod(Sq, bq)
    body = jax.checkpoint(chunk_attn)

    def scan_fn(_, it):
        q_blk, base = it
        return None, body(q_blk, base)

    q_main = q[:, :nq * bq].reshape(B, nq, bq, H, dh).transpose(1, 0, 2, 3, 4)
    bases = q_offset + jnp.arange(nq) * bq
    _, outs = jax.lax.scan(scan_fn, None, (q_main, bases),
                           unroll=CONFIG["unroll"])
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * bq, H, dh)
    if rem:
        out = jnp.concatenate(
            [out, body(q[:, nq * bq:], q_offset + nq * bq)], axis=1)
    return out


# ---------------------------------------------------------------------------
# RG-LRU recurrence
# ---------------------------------------------------------------------------
def rglru(x, log_a, h0=None, *, impl="auto"):
    """h_t = exp(log_a_t)*h_{t-1} + x_t.  x/log_a: (B,S,D), h0: (B,D).

    Returns (h (B,S,D), h_last (B,D))."""
    impl = resolve_impl(impl)
    B, S, D = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)
    if S == 1:  # decode step
        h = jnp.exp(log_a.astype(jnp.float32)) * h0[:, None] \
            + x.astype(jnp.float32)
        return h.astype(x.dtype), h[:, 0]
    if impl == "xla":
        a = jnp.exp(log_a.astype(jnp.float32))
        xf = x.astype(jnp.float32)

        def comb(c1, c2):
            a1, h1 = c1
            a2, h2 = c2
            return a1 * a2, a2 * h1 + h2

        # fold h0 into the first step
        xf = xf.at[:, 0].add(a[:, 0] * h0)
        af, hf = jax.lax.associative_scan(comb, (a, xf), axis=1)
        return hf.astype(x.dtype), hf[:, -1]
    interpret = impl == "kernel_interpret"
    bd = 256 if D % 256 == 0 else D
    bs = 256 if S % 256 == 0 else S
    return _rg.rglru_scan(x, log_a, h0, block_s=bs, block_d=bd,
                          interpret=interpret)


# ---------------------------------------------------------------------------
# RWKV-6 WKV
# ---------------------------------------------------------------------------
def wkv(r, k, v, w, u, s0=None, *, impl="auto"):
    """RWKV-6 recurrence.  r/k/v/w: (B,S,H,dh) model layout; u: (H,dh).

    s0: (B,H,dh,dh) f32.  Returns (o (B,S,H,dh), s_last (B,H,dh,dh))."""
    impl = resolve_impl(impl)
    B, S, H, dh = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    if S == 1:  # decode step
        rf, kf, vf, wf = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
        uf = u.astype(jnp.float32)
        kv = kf[..., :, None] * vf[..., None, :]
        o = jnp.einsum("bhk,bhkv->bhv", rf, s0 + uf[..., :, None] * kv)
        s = wf[..., :, None] * s0 + kv
        return o[:, None].astype(r.dtype), s
    if impl == "xla":
        return _wkv_xla_chunked(r, k, v, w, u, s0)
    interpret = impl == "kernel_interpret"
    # kernel layout (B, H, S, dh)
    rt, kt, vt, wt = (t.transpose(0, 2, 1, 3) for t in (r, k, v, w))
    bs = 256 if S % 256 == 0 else S
    o, s_last = _wk.wkv6(rt, kt, vt, wt, u, s0, block_s=bs,
                         interpret=interpret)
    return o.transpose(0, 2, 1, 3), s_last


def _wkv_xla_chunked(r, k, v, w, u, s0, chunk=128):
    """Time-chunked WKV with per-chunk remat.

    The naive full-sequence scan stores a (B,H,dh,dh) state residual per
    STEP for backward — 274 GB/device at rwkv6-7b train_4k (measured,
    EXPERIMENTS.md §Perf iter 2).  Chunking with jax.checkpoint keeps
    only per-chunk boundary states and recomputes inside the chunk."""
    B, S, H, dh = r.shape
    c = min(chunk, S)
    if S % c:
        return ref.wkv6_ref(r, k, v, w, u, s0)
    nc = S // c

    from repro.sharding.specs import constrain, DP

    def chunk_fn(s, xs):
        rc, kc, vc, wc = xs                      # (B, c, H, dh)
        o, s2 = ref.wkv6_ref(rc, kc, vc, wc, u, s)
        return s2, constrain(o, DP, None, "model", None)

    xs = tuple(constrain(t.reshape(B, nc, c, H, dh).swapaxes(0, 1),
                         None, DP, None, "model", None)
               for t in (r, k, v, w))
    s_last, outs = jax.lax.scan(jax.checkpoint(chunk_fn), s0, xs,
                                unroll=CONFIG["unroll"])
    return outs.swapaxes(0, 1).reshape(B, S, H, dh), s_last


# ---------------------------------------------------------------------------
# Vote aggregation
# ---------------------------------------------------------------------------
def _top2_of(scores, argmax_labels, num_classes):
    """(top1, top2) with only the argmax POSITION masked, so exact ties
    give top2 == top1 (top_k semantics)."""
    top1 = jnp.max(scores, axis=-1)
    masked = jnp.where(
        jax.nn.one_hot(argmax_labels, num_classes, dtype=bool),
        NEG_INF, scores)
    return top1, jnp.max(masked, axis=-1)


def _votes_kernel(preds, num_classes, noise, interpret):
    T = preds.shape[1]
    if noise is None:
        noise = jnp.zeros((T, num_classes), jnp.float32)
    bt = 128 if T % 128 == 0 else T
    bu = 512 if num_classes % 512 == 0 else num_classes
    return _va.vote_aggregate(preds, noise, num_classes=num_classes,
                              block_t=bt, block_u=bu, interpret=interpret)


def votes(preds, num_classes, noise=None, *, impl="auto"):
    """Max-vote labels + top-2 vote scores.

    preds: (M, T) int32; noise: optional (T, U) f32.
    Returns (labels (T,) i32, top1 (T,) f32, top2 (T,) f32)."""
    impl = resolve_impl(impl)
    if noise is None and num_classes > 2048:
        # LM-scale noise-free voting: O(M log M), no U-sized tensors
        return votes_sort(preds)
    if impl == "xla":
        labels, counts = ref.vote_aggregate_ref(preds, num_classes, noise)
        scores = counts.astype(jnp.float32)
        if noise is not None:
            scores = scores + noise
        top1, top2 = _top2_of(scores, labels, num_classes)
        return labels, top1, top2
    labels, top1, top2, _, _ = _votes_kernel(
        preds, num_classes, noise, impl == "kernel_interpret")
    return labels, top1, top2


def votes_with_clean(preds, num_classes, noise=None, *, impl="auto"):
    """Noisy max-vote labels + CLEAN top-2 from ONE histogram build.

    The party-side vote hot path needs both the noised argmax (the label
    it answers with) and the pre-noise gap (the Lemma-7 privacy input);
    building the (T, U) histogram once serves both.  Returns
    (labels, counts, clean_top1, clean_top2) where ``counts`` is the
    clean histogram on the xla path and None on the kernel paths (the
    blocked kernel never materializes it — it emits clean top-2
    directly) and on the LM-scale sort path."""
    impl = resolve_impl(impl)
    if noise is None and num_classes > 2048:
        labels, top1, top2 = votes_sort(preds)
        return labels, None, top1, top2
    if impl == "xla":
        clean_labels, counts = ref.vote_aggregate_ref(preds, num_classes)
        cf = counts.astype(jnp.float32)
        c1, c2 = _top2_of(cf, clean_labels, num_classes)
        if noise is None:
            return clean_labels, counts, c1, c2
        labels = jnp.argmax(cf + noise, axis=-1).astype(jnp.int32)
        return labels, counts, c1, c2
    labels, _, _, c1, c2 = _votes_kernel(
        preds, num_classes, noise, impl == "kernel_interpret")
    return labels, None, c1, c2


def votes_sort(preds):
    """Vocabulary-free max voting: mode along the teacher axis via sort.

    preds: (M, T) int32.  Returns (labels, top1, top2) like ``votes`` —
    but cost is O(M log M) per query with NO U-sized tensor, which is
    what the FedKT label step needs at LM scale (U = 200k vocab would
    make even the blocked histogram's noise input (T, U) infeasible).
    Noise-free (privacy level L0); DP label steps use the blocked kernel.
    Ties resolve to the smallest class id (matches ref argmax).
    """
    M, T = preds.shape
    s = jnp.sort(preds, axis=0)                       # (M, T)
    # run length ending at i: rl[i] = rl[i-1]+1 if equal else 1
    def body(carry, row):
        prev, rl = carry
        rl = jnp.where(row == prev, rl + 1, 1)
        return (row, rl), rl

    init = (jnp.full((T,), -1, preds.dtype), jnp.zeros((T,), jnp.int32))
    _, rls = jax.lax.scan(body, init, s)              # (M, T) run lengths
    # winner: value whose run is longest; first (smallest) on ties
    best = jnp.argmax(rls, axis=0)                    # last index of run
    labels = jnp.take_along_axis(s, best[None], axis=0)[0]
    top1 = jnp.max(rls, axis=0).astype(jnp.float32)
    # second: longest run among values != winner
    masked = jnp.where(s == labels[None], 0, rls)
    top2 = jnp.max(masked, axis=0).astype(jnp.float32)
    return labels.astype(jnp.int32), top1, top2


# ---------------------------------------------------------------------------
# Tree-fit histogram
# ---------------------------------------------------------------------------
def tree_hist(xb, node, w, *, num_nodes, num_bins, impl="auto",
              block_f=32):
    """Weighted (channel, node, feature, bin) histogram — the per-level
    build inside the histogram tree fits.

    xb: (N, F) int32 binned features; node: (N,) int32 tree position of
    each sample; w: (K, N) f32 channel weights.  Returns
    (K, num_nodes, F, num_bins) f32 counts; rows at w == 0 contribute
    exact zeros (the stacked-fit padding invariant).

    The xla path is NOT the scatter-add this replaces: it contracts a
    weighted (N, num_nodes*K) node/channel one-hot against per-feature-
    block (N, bf*num_bins) bin one-hots — a dense matmul XLA lowers
    without the serialized scatter loop or the (N, F) broadcast of w.
    """
    impl = resolve_impl(impl)
    if impl == "xla":
        return _tree_hist_xla(xb, node, w, num_nodes, num_bins, block_f)
    return _th.tree_hist(xb, node, w, num_nodes=num_nodes,
                         num_bins=num_bins, block_f=block_f,
                         interpret=impl == "kernel_interpret")


def _tree_hist_xla(xb, node, w, num_nodes, num_bins, block_f):
    N, F = xb.shape
    K = w.shape[0]
    nc = jax.nn.one_hot(node, num_nodes, dtype=jnp.float32)     # (N, n)
    ncw = w.astype(jnp.float32)[:, :, None] * nc[None]          # (K, N, n)

    def chunk(xc):  # (N, bf) -> (K, n, bf, B)
        ob = jax.nn.one_hot(xc, num_bins, dtype=jnp.float32)
        return jnp.einsum("kin,ifb->knfb", ncw, ob)

    if F <= block_f:
        return chunk(xb)
    # feature-blocked: bounds the (N, bf, B) one-hot to one block
    pad = (-F) % block_f
    xp = jnp.pad(xb, ((0, 0), (0, pad))) if pad else xb
    nf = (F + pad) // block_f
    xs = xp.reshape(N, nf, block_f).transpose(1, 0, 2)
    _, hs = jax.lax.scan(lambda c, xc: (c, chunk(xc)), None, xs,
                         unroll=CONFIG["unroll"])
    h = hs.transpose(1, 2, 0, 3, 4).reshape(K, num_nodes, nf * block_f,
                                            num_bins)
    return h[:, :, :F]


def node_hist(node, w, *, num_nodes, impl="auto"):
    """Weighted per-node histogram — the leaf builds of the tree fits.

    node: (N,) int32; w: (K, N) f32.  Returns (K, num_nodes) f32.  The
    leaf build IS a tree_hist with the node id as the single "feature"
    and the leaves as its bins, so both impls reuse that machinery."""
    out = tree_hist(node[:, None], jnp.zeros_like(node), w,
                    num_nodes=1, num_bins=num_nodes, impl=impl)
    return out[:, 0, 0, :]
