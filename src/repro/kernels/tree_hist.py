"""Blocked (node, feature, bin) histogram Pallas kernel — the tree-fit
hot path.

Every depth level of the histogram tree learners (trees.py) needs

    hist[k, n, f, b] = sum_i w[k, i] * [node_i == n] * [xb[i, f] == b]

for K weight channels (the C class-masked sample weights of a gini
tree, or the (g, h) gradient/hessian pair of a GBDT tree).  The naive
XLA lowering is one giant 1-D scatter-add over an (N, F) broadcast of
w — memory-bound and serialized by the scatter loop.

TPU-native reformulation (the ``vote_aggregate`` pattern): the grid
walks (feature-block, sample-block) with samples innermost.  Each step
builds two one-hot operands on the VPU — the (bs, num_nodes) node mask
scaled by a weight channel, and the (bs, bf * B) bin mask — and
contracts them over the sample axis with one MXU matmul per channel,
accumulating into the revisited output block (``pl.when`` zero-init on
the first sample step).  Rows padded to the sample-block multiple ride
at w == 0, so they contribute exact zeros — the same invariant the
stacked (teacher-axis) fits rely on for padding rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xb_ref, node_ref, w_ref, out_ref, *, K, num_nodes, num_bins,
            bs, bf):
    i_s = pl.program_id(1)

    @pl.when(i_s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    node_b = node_ref[...]                                      # (bs,)
    n_iota = jax.lax.broadcasted_iota(jnp.int32, (bs, num_nodes), 1)
    onehot_n = (node_b[:, None] == n_iota).astype(jnp.float32)  # (bs, n)

    xb_b = xb_ref[...]                                          # (bs, bf)
    b_iota = jax.lax.broadcasted_iota(jnp.int32, (bs, bf, num_bins), 2)
    onehot_b = (xb_b[:, :, None] == b_iota).astype(jnp.float32)
    onehot_b = onehot_b.reshape(bs, bf * num_bins)

    w_b = w_ref[...]                                            # (bs, K)
    contrib = []
    for k in range(K):                       # static channel unroll
        nck = onehot_n * w_b[:, k][:, None]                     # (bs, n)
        contrib.append(jax.lax.dot_general(
            nck, onehot_b, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32))   # (n, bf*B) on the MXU
    out_ref[...] += jnp.concatenate(contrib, axis=0)


@functools.partial(jax.jit, static_argnames=(
    "num_nodes", "num_bins", "block_s", "block_f", "interpret"))
def tree_hist(xb, node, w, *, num_nodes, num_bins, block_s=512,
              block_f=None, interpret=False):
    """xb: (N, F) int32 bins; node: (N,) int32; w: (K, N) f32 channel
    weights.  Returns (K, num_nodes, F, num_bins) f32 weighted counts.
    """
    N, F = xb.shape
    K = w.shape[0]
    bs = min(block_s, N)
    bf = min(block_f or F, F)

    pad_s, pad_f = (-N) % bs, (-F) % bf
    if pad_s:  # padded samples ride at w == 0: exact-zero contribution
        xb = jnp.pad(xb, ((0, pad_s), (0, 0)))
        node = jnp.pad(node, (0, pad_s))
        w = jnp.pad(w, ((0, 0), (0, pad_s)))
    if pad_f:  # junk feature columns, sliced off below
        xb = jnp.pad(xb, ((0, 0), (0, pad_f)))
    ns, nf = (N + pad_s) // bs, (F + pad_f) // bf

    kern = functools.partial(_kernel, K=K, num_nodes=num_nodes,
                             num_bins=num_bins, bs=bs, bf=bf)
    out = pl.pallas_call(
        kern,
        grid=(nf, ns),
        in_specs=[
            pl.BlockSpec((bs, bf), lambda i_f, i_s: (i_s, i_f)),
            pl.BlockSpec((bs,), lambda i_f, i_s: (i_s,)),
            pl.BlockSpec((bs, K), lambda i_f, i_s: (i_s, 0)),
        ],
        out_specs=pl.BlockSpec((K * num_nodes, bf * num_bins),
                               lambda i_f, i_s: (0, i_f)),
        out_shape=jax.ShapeDtypeStruct(
            (K * num_nodes, nf * bf * num_bins), jnp.float32),
        interpret=interpret,
    )(xb, node, w.T)
    out = out.reshape(K, num_nodes, nf * bf, num_bins)
    return out[:, :, :F]
