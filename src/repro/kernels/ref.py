"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: small, obviously-correct, memory-naive
implementations.  Kernel tests sweep shapes/dtypes and assert_allclose
against these; ``ops.py`` also dispatches to (chunked variants of) these on
non-TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                  q_offset=0):
    """Naive softmax attention oracle.

    q: (B, Sq, H, dh); k, v: (B, Skv, KV, dh) with H % KV == 0.
    ``q_offset`` is the absolute position of q[0] (for decode, Skv-1).
    ``window``: sliding window size (0 = unbounded).
    Returns (B, Sq, H, dh) in q.dtype.
    """
    B, Sq, H, dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    g = H // KV
    qf = q.astype(jnp.float32) * (dh ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to H
    kf = jnp.repeat(kf, g, axis=2)
    vf = jnp.repeat(vf, g, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------
def rglru_scan_ref(x, log_a, h0=None):
    """h_t = exp(log_a_t) * h_{t-1} + x_t, scanned over axis 1.

    x, log_a: (B, S, D) (x already carries the sqrt(1-a^2)*gated-input
    factor; the block computes that).  Returns (h, h_last) where h is
    (B, S, D) and h_last is (B, D).
    """
    xf = x.astype(jnp.float32)
    af = log_a.astype(jnp.float32)
    B, S, D = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)

    def step(h, t):
        xt, at = t
        h = jnp.exp(at) * h + xt
        return h, h

    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                              (xf.swapaxes(0, 1), af.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(x.dtype), h_last


# ---------------------------------------------------------------------------
# RWKV-6 WKV recurrence
# ---------------------------------------------------------------------------
def wkv6_ref(r, k, v, w, u, s0=None):
    """RWKV-6 token-mixing recurrence oracle.

    r, k, v, w: (B, S, H, dh); u: (H, dh).  w is the per-step decay in
    (0, 1) (already exp(-exp(...))-transformed by the block).
    state s: (B, H, dh_k, dh_v).
      o_t = r_t . (s + (u*k_t) v_t^T);  s <- w_t[:,None] * s + k_t v_t^T
    Returns (o, s_last): o (B, S, H, dh), s_last (B, H, dh, dh).
    """
    B, S, H, dh = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((B, H, dh, dh), jnp.float32)

    def step(s, t):
        rt, kt, vt, wt = t  # (B, H, dh)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,dhk,dhv)
        o = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, o

    xs = tuple(t.swapaxes(0, 1) for t in (rf, kf, vf, wf))
    s_last, os_ = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return os_.swapaxes(0, 1).astype(r.dtype), s_last


# ---------------------------------------------------------------------------
# Tree-fit histogram (the tree learners' per-level hot path)
# ---------------------------------------------------------------------------
def tree_hist_ref(xb, node, w, num_nodes, num_bins):
    """Weighted (channel, node, feature, bin) histogram oracle.

    xb: (N, F) int32 binned features; node: (N,) int32 current tree node
    of each sample; w: (K, N) float32 channel weights (class-masked
    sample weights for a gini tree, (g, h) for a GBDT tree).  Returns
    (K, num_nodes, F, num_bins) float32:

        hist[k, n, f, b] = sum_i w[k, i] [node_i == n] [xb[i, f] == b]
    """
    onehot_n = jax.nn.one_hot(node, num_nodes, dtype=jnp.float32)
    onehot_b = jax.nn.one_hot(xb, num_bins, dtype=jnp.float32)
    return jnp.einsum("ki,in,ifb->knfb", w.astype(jnp.float32),
                      onehot_n, onehot_b)


# ---------------------------------------------------------------------------
# PATE vote aggregation (the paper's core op)
# ---------------------------------------------------------------------------
def vote_aggregate_ref(preds, num_classes, noise=None):
    """Teacher-ensemble max voting.

    preds: (M, T) int32 — class prediction of each of M teachers for each
    of T queries.  noise: optional (T, num_classes) float32 Laplace noise
    added to the vote histogram before the argmax (the paper's
    gamma-mechanism).  Returns (labels (T,) int32, counts (T, U) int32).
    """
    onehot = jax.nn.one_hot(preds, num_classes, dtype=jnp.int32)  # (M,T,U)
    counts = onehot.sum(0)                                        # (T, U)
    scores = counts.astype(jnp.float32)
    if noise is not None:
        scores = scores + noise.astype(jnp.float32)
    labels = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    return labels, counts
