"""Blocked (flash) attention Pallas kernel for TPU.

Targets the teacher-ensemble prefill workload (the dominant compute in
FedKT's knowledge-transfer phase): online-softmax attention tiled so the
working set (one q block, one kv block, f32 accumulators) lives in VMEM.

Layout: q (B, H, Sq, dh), k/v (B, KV, Skv, dh), GQA via index_map
(kv head = h // (H // KV)).  Grid (B, H, nq, nk) — nk innermost so the
running max / denominator / accumulator scratch carries across kv blocks
(TPU grid execution is sequential over the trailing axis).

Supports causal masking, sliding windows (gemma2/mixtral/recurrentgemma
local attention, and the long_500k SWA variant), gemma2 logit soft-capping,
and a ``q_offset`` for chunked prefill.

MXU alignment: block shapes default to (bq, dh) = (256, 128) and
(bk, dh) = (512, 128) — multiples of the 128-lane MXU tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, softcap, q_offset, bq, bk, nk):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    iq = pl.program_id(2)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + q_offset
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    # p is explicitly re-masked so fully-masked blocks contribute zero even
    # when m_new is still NEG_INF.
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v).astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "q_offset",
                     "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    q_offset=0, block_q=256, block_k=512, interpret=False):
    """q: (B, H, Sq, dh); k, v: (B, KV, Skv, dh).  Returns (B, H, Sq, dh).

    Sq must divide by block_q and Skv by block_k (ops.py pads).
    """
    B, H, Sq, dh = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    g = H // KV
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    nq, nk = Sq // bq, Skv // bk
    assert Sq % bq == 0 and Skv % bk == 0

    kern = functools.partial(
        _kernel, scale=dh ** -0.5, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, bq=bq, bk=bk, nk=nk)

    return pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, iq, ik, g=g: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, iq, ik, g=g: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((bq, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
