from repro.sharding.specs import (  # noqa: F401
    DP, batch_sharding, cache_sharding, constrain, opt_state_sharding,
    param_shardings, pregather_params, replicated, set_activation_mesh,
    spec_for_param,
)
