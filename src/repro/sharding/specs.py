"""Name-based sharding rules: params -> PartitionSpec.

Baseline layout (DESIGN.md §6):
  - tensor parallel over "model": column weights shard their output dim,
    row weights shard their input dim (Megatron pairing, so the pair
    needs one reduce per block)
  - FSDP over "data": the *other* matmul dim of every large weight is
    sharded over the data axis (ZeRO-3 style; XLA inserts the
    all-gathers); optimizer state inherits the param spec, so Adam for a
    27B model fits 16 GB chips
  - "pod" is pure data parallelism (batch + gradient psum)

Specs are right-aligned to leaf rank, so scan-stacked (periods) leaves
pick up a leading None automatically.  Any axis that does not divide the
dim is dropped (e.g. 24 heads on a 16-way model axis -> the flattened
head*dh dim is sharded instead, which always divides).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# weight-name classes (last path component)
COLUMN = {"wq", "wk", "wv", "w_up", "w_gate", "w_in", "w_a", "w_x",
          "w_r", "w_k", "w_v", "w_g", "cm_w_up", "cm_w_r", "w_lora_b"}
ROW = {"wo", "w_down", "w_out", "cm_w_down"}
VEC_MODEL = {"conv_b", "lam", "w0"}        # (…, D)-vectors in sharded space
HEAD_MAJOR = {"u", "ln_scale"}             # (…, H, dh)
REPLICATED = {"scale", "bias", "router", "mu_r", "mu_k", "mu_v", "mu_w",
              "mu_g", "cm_mu_k", "cm_mu_r", "w_lora_a", "conv_w"}


def _axis_fits(dim: int, mesh: Mesh, name: str) -> bool:
    return name in mesh.shape and dim % mesh.shape[name] == 0


def _spec(shape, mesh, *, model_dim=None, data_dim=None):
    """Builds a PartitionSpec placing 'model'/'data' at the given
    (negative) dims when divisible."""
    ndim = len(shape)
    axes = [None] * ndim
    if model_dim is not None and _axis_fits(shape[model_dim], mesh, "model"):
        axes[model_dim] = "model"
    if data_dim is not None and axes[data_dim] is None \
            and _axis_fits(shape[data_dim], mesh, "data"):
        axes[data_dim] = "data"
    return P(*axes)


def spec_for_param(path: Tuple[str, ...], shape, mesh: Mesh) -> P:
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    if name == "table":                      # embedding (V, D)
        return _spec(shape, mesh, model_dim=-2, data_dim=-1)
    if name == "w" and parent == "lm_head":  # (D, V)
        return _spec(shape, mesh, model_dim=-1, data_dim=-2)
    if name in COLUMN:
        return _spec(shape, mesh, model_dim=-1, data_dim=-2)
    if name in ROW:
        return _spec(shape, mesh, model_dim=-2, data_dim=-1)
    if name in VEC_MODEL:
        return _spec(shape, mesh, model_dim=-1)
    if name in HEAD_MAJOR:
        return _spec(shape, mesh, model_dim=-2)
    return P()                               # replicated


def _path_names(kp) -> Tuple[str, ...]:
    out = []
    for k in kp:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def param_shardings(param_shapes, mesh: Mesh):
    """param_shapes: pytree of ShapeDtypeStruct (from eval_shape)."""
    def f(kp, leaf):
        return NamedSharding(mesh, spec_for_param(_path_names(kp),
                                                  leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(f, param_shapes)


def batch_sharding(batch_shapes, mesh: Mesh):
    """Shard the leading (batch) dim over pod+data when divisible."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))

    def f(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % dp == 0 and dp > 1:
            return NamedSharding(mesh, P(dp_axes))
        return NamedSharding(mesh, P())
    return jax.tree.map(f, batch_shapes)


def cache_sharding(cache_shapes, mesh: Mesh, batch_size: int):
    """KV caches (…, B, L, KV, dh) / recurrent states: batch dim (located
    by size match — stacked period caches carry a leading layer dim) over
    pod+data; kv-heads (or head_dim) over model."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    msize = mesh.shape.get("model", 1)

    def f(leaf):
        axes = [None] * leaf.ndim
        bdim = None
        if dp > 1 and batch_size % dp == 0 and batch_size >= dp:
            for d in range(leaf.ndim):
                if leaf.shape[d] == batch_size:
                    axes[d] = dp_axes
                    bdim = d
                    break
        if msize > 1:
            for d in (leaf.ndim - 2, leaf.ndim - 1):
                if 0 <= d < leaf.ndim and d != bdim \
                        and leaf.shape[d] % msize == 0 \
                        and leaf.shape[d] >= msize:
                    axes[d] = "model"
                    break
        return NamedSharding(mesh, P(*axes))
    return jax.tree.map(f, cache_shapes)


def opt_state_sharding(opt_shapes, pspec_tree, mesh: Mesh):
    """Adam mu/nu inherit the param spec; step is replicated."""
    import jax.numpy as jnp

    def f(leaf):
        return NamedSharding(mesh, P())

    # OptState(step, mu, nu) where mu/nu mirror params
    from repro.optim import OptState
    step_s = NamedSharding(mesh, P())
    return OptState(step_s, pspec_tree, pspec_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Activation sharding constraints (set by launchers; no-op otherwise)
# ---------------------------------------------------------------------------
_ACT_MESH: list = [None]


def set_activation_mesh(mesh: Optional[Mesh]):
    """Launchers install the mesh so model code can pin activation
    layouts (jax.lax.with_sharding_constraint).  Without this, GSPMD
    replicates attention score compute whenever heads don't divide the
    model axis (measured 16x on phi4 — EXPERIMENTS.md §Perf)."""
    _ACT_MESH[0] = mesh


def constrain(x, *axes):
    """with_sharding_constraint(x, P(*axes)) with per-dim divisibility
    checks; axes entries are mesh-axis names, tuples, or None.  Any axis
    that doesn't divide the corresponding dim is dropped."""
    mesh = _ACT_MESH[0]
    if mesh is None:
        return x
    fixed = []
    for d, a in enumerate(axes):
        if a is None:
            fixed.append(None)
            continue
        names = a if isinstance(a, tuple) else (a,)
        names = tuple(n for n in names if n in mesh.shape)
        size = int(np.prod([mesh.shape[n] for n in names])) if names else 1
        if names and x.shape[d] % size == 0 and x.shape[d] >= size:
            fixed.append(names if len(names) > 1 else names[0])
        else:
            fixed.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


DP = ("pod", "data")  # canonical data-parallel axis group


def pregather_params(params, dtype):
    """ZeRO-3 'gather once per step': cast params to the compute dtype
    and pin a spec with the FSDP ('data') axis removed, so XLA issues ONE
    bf16 all-gather per weight per step (outside the layer scan) instead
    of per-layer f32 gathers re-issued under remat.  Differentiable: the
    backward of the cast+constraint is the f32 reduce-scatter ZeRO wants.
    No-op without an activation mesh."""
    mesh = _ACT_MESH[0]
    if mesh is None:
        return jax.tree.map(
            lambda p: p.astype(dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)

    def f(kp, p):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p
        path = _path_names(kp)
        spec = spec_for_param(path, p.shape, mesh)
        if p.ndim >= 4:
            # stacked MoE expert weights (periods, E, D, F): gathering
            # the FSDP axis makes GSPMD replicate the expert einsums
            # over `data` (measured 12x FLOPs / 326 GB on mixtral —
            # §Perf iter 7b).  Keep the FSDP spec; cast only.
            return jax.lax.with_sharding_constraint(
                p.astype(dtype), NamedSharding(mesh, spec))
        spec = P(*[None if a == "data" else a for a in spec])
        return jax.lax.with_sharding_constraint(
            p.astype(dtype), NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(f, params)


def shard_heads(x):
    """Pin attention-tensor layout (B, S, N, dh).

    Preference order: heads over 'model' (Megatron); else spread the
    batch over every mesh axis (batch-parallel attention — heads that
    don't divide the model axis, e.g. phi4's 24 or recurrentgemma's 10);
    else batch over data-parallel axes only."""
    mesh = _ACT_MESH[0]
    if mesh is None or x.ndim != 4:
        return x
    B, S, N, dh = x.shape
    msize = mesh.shape.get("model", 1)
    if N % msize == 0 and N >= msize:
        return constrain(x, DP, None, "model", None)
    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    tot = int(np.prod([mesh.shape[a] for a in all_axes]))
    if B % tot == 0 and B >= tot:
        return constrain(x, all_axes, None, None, None)
    return constrain(x, DP, None, None, None)
